//! Fixed-page block allocator over one preallocated per-layer K/V arena.
//!
//! The paper's Limitations flag the BF16 KV cache as the dominant
//! transient memory on edge devices; the seed design leased whole
//! `seq_len`-sized contiguous caches, so admission was capped by
//! worst-case allocation. Here KV memory is a single arena per layer,
//! carved into fixed pages of `page_size` positions. Sequences map
//! logical positions onto pages through a [`BlockTable`]
//! (`super::table`); pages are refcounted so a frozen prompt prefix can
//! back any number of sequences at once (radix sharing, `super::prefix`).
//!
//! The arena's *bytes* live behind a [`PageStore`] (`super::store`): the
//! allocator owns page lifecycle (refcounts, free stack, high-water
//! marks) while the store owns the storage dtype — f32 for the parity
//! baseline, int8 with per-page-per-head scales for the quantized cache.
//!
//! [`BlockTable`]: super::table::BlockTable

use super::store::{new_store, KvDtype, PageStore, Plane};
use crate::engine::NativeConfig;

/// Index of a page in the arena.
pub use super::store::PageId;

/// Refcounted fixed-page arena for K and V, one plane per layer, bytes
/// held by a dtype-polymorphic [`PageStore`].
pub struct BlockAllocator {
    page_size: usize,
    d_model: usize,
    num_pages: usize,
    store: Box<dyn PageStore>,
    /// Per-page reference counts (0 = free).
    refs: Vec<u32>,
    /// Free-page stack.
    free: Vec<PageId>,
    peak_used: usize,
}

impl BlockAllocator {
    /// f32 arena (the parity baseline) with `num_pages` pages of
    /// `page_size` positions each, shaped for `cfg`.
    pub fn new(cfg: &NativeConfig, num_pages: usize, page_size: usize) -> Self {
        Self::new_with(cfg, num_pages, page_size, KvDtype::F32)
    }

    /// Arena storing pages at `dtype`.
    pub fn new_with(cfg: &NativeConfig, num_pages: usize, page_size: usize, dtype: KvDtype) -> Self {
        assert!(num_pages > 0 && page_size > 0, "arena must hold at least one slot");
        assert!(num_pages <= PageId::MAX as usize, "page id space exhausted");
        Self {
            page_size,
            d_model: cfg.d_model,
            num_pages,
            store: new_store(cfg, num_pages, page_size, dtype),
            refs: vec![0; num_pages],
            // Pop order is descending ids; purely cosmetic.
            free: (0..num_pages as PageId).rev().collect(),
            peak_used: 0,
        }
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    pub fn d_model(&self) -> usize {
        self.d_model
    }

    pub fn num_pages(&self) -> usize {
        self.num_pages
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn used_pages(&self) -> usize {
        self.num_pages - self.free.len()
    }

    /// High-water mark of pages in use (block-utilization gauge).
    pub fn peak_used(&self) -> usize {
        self.peak_used
    }

    /// Current reference count of `p` (0 = free).
    pub fn ref_count(&self, p: PageId) -> u32 {
        self.refs[p as usize]
    }

    /// Storage dtype policy of this arena.
    pub fn dtype(&self) -> KvDtype {
        self.store.dtype()
    }

    /// The storage backend (block reads and byte accounting go through
    /// here; see [`PageStore`]).
    #[inline]
    pub fn store(&self) -> &dyn PageStore {
        self.store.as_ref()
    }

    /// Total arena bytes at the storage dtype (KV byte budget).
    pub fn bytes(&self) -> usize {
        self.store.bytes()
    }

    /// Bytes one stored position costs (kv-bytes-per-token gauge).
    pub fn bytes_per_token(&self) -> usize {
        self.store.bytes_per_token()
    }

    /// Take a free page with refcount 1, or `None` when the arena is
    /// full. Pages on the free stack are already reset: stores start
    /// zeroed and [`BlockAllocator::release`] resets eagerly on the last
    /// reference drop, so no per-alloc store work happens here.
    pub fn alloc(&mut self) -> Option<PageId> {
        let p = self.free.pop()?;
        debug_assert_eq!(self.refs[p as usize], 0, "free page with live refs");
        self.refs[p as usize] = 1;
        self.store.set_page_leases(p, 1);
        debug_assert!(!self.store.is_frozen(p), "free page still frozen");
        self.peak_used = self.peak_used.max(self.used_pages());
        Some(p)
    }

    /// Add a reference to a live page (prefix sharing).
    pub fn retain(&mut self, p: PageId) {
        assert!(self.refs[p as usize] > 0, "retain of a free page");
        self.refs[p as usize] += 1;
        self.store.set_page_leases(p, self.refs[p as usize]);
    }

    /// Freeze a live page's bytes and quantizer state (prefix-index
    /// registration). The page must be *full* — every slot written — so
    /// it can be materialized whole; it thaws the moment its last
    /// reference is released ([`BlockAllocator::release`] resets the
    /// page and drops its cached tiles eagerly).
    pub fn freeze_page(&mut self, p: PageId) {
        debug_assert!(self.refs[p as usize] > 0, "freeze of a free page");
        self.store.freeze_page(p);
    }

    /// Resize the store's frozen-tile cache (0 disables); see
    /// [`PageStore::set_tile_cache_capacity`].
    pub fn set_tile_cache_capacity(&mut self, tiles: usize) {
        self.store.set_tile_cache_capacity(tiles);
    }

    /// Enable/disable the integer a·V accumulation path; see
    /// [`PageStore::set_integer_av`].
    pub fn set_integer_av(&mut self, on: bool) {
        self.store.set_integer_av(on);
    }

    /// Drop one reference; the page returns to the free stack at zero.
    /// A freed page is reset immediately (thawed, quantizer state
    /// cleared, cached tiles invalidated) rather than lazily at
    /// reallocation, so a dead page's tiles never occupy the bounded
    /// tile cache or pin memory while the page sits on the free stack.
    pub fn release(&mut self, p: PageId) {
        let r = &mut self.refs[p as usize];
        assert!(*r > 0, "double free of page {p}");
        *r -= 1;
        let refs = *r;
        self.store.set_page_leases(p, refs);
        if refs == 0 {
            self.free.push(p);
            self.store.reset_page(p);
        }
    }

    /// Write one position's K and V rows into `(page, slot)` of `layer`.
    #[inline]
    pub fn write_row(
        &mut self,
        layer: usize,
        p: PageId,
        slot: usize,
        k_row: &[f32],
        v_row: &[f32],
    ) {
        debug_assert!(slot < self.page_size);
        debug_assert!(self.refs[p as usize] > 0, "write to a free page");
        self.store.write_row(layer, p, slot, k_row, v_row);
    }

    /// The first `rows` rows of page `p` on `plane` at `layer` as f32
    /// (borrowed for f32 storage, dequantized into `scratch` otherwise).
    #[inline]
    pub fn read_block<'a>(
        &'a self,
        plane: Plane,
        layer: usize,
        p: PageId,
        rows: usize,
        scratch: &'a mut Vec<f32>,
    ) -> &'a [f32] {
        self.store.block(plane, layer, p, rows, scratch)
    }

    /// Copy the first `rows` slots of `src` into `dst` across every layer
    /// (copy-on-write: the diverging sequence gets a private copy of the
    /// shared page's prefix; `src` itself is never written). Goes through
    /// the store so quantizer state travels with the bytes.
    pub fn copy_rows(&mut self, src: PageId, dst: PageId, rows: usize) {
        debug_assert!(rows <= self.page_size);
        self.store.copy_rows(src, dst, rows);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arena(pages: usize) -> BlockAllocator {
        BlockAllocator::new(&NativeConfig::named("nano").unwrap(), pages, 4)
    }

    #[test]
    fn alloc_release_roundtrip() {
        let mut a = arena(3);
        assert_eq!(a.free_pages(), 3);
        let p = a.alloc().unwrap();
        assert_eq!(a.ref_count(p), 1);
        assert_eq!(a.used_pages(), 1);
        a.release(p);
        assert_eq!(a.free_pages(), 3);
        assert_eq!(a.ref_count(p), 0);
    }

    #[test]
    fn exhaustion_returns_none() {
        let mut a = arena(2);
        let _p = a.alloc().unwrap();
        let _q = a.alloc().unwrap();
        assert!(a.alloc().is_none());
    }

    #[test]
    fn retain_keeps_page_alive() {
        let mut a = arena(2);
        let p = a.alloc().unwrap();
        a.retain(p);
        a.release(p);
        assert_eq!(a.ref_count(p), 1, "still referenced");
        assert_eq!(a.used_pages(), 1);
        a.release(p);
        assert_eq!(a.used_pages(), 0);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut a = arena(2);
        let p = a.alloc().unwrap();
        a.release(p);
        a.release(p);
    }

    #[test]
    fn rows_written_are_read_back() {
        let cfg = NativeConfig::named("nano").unwrap();
        let d = cfg.d_model;
        let mut a = BlockAllocator::new(&cfg, 2, 4);
        let p = a.alloc().unwrap();
        let krow: Vec<f32> = (0..d).map(|i| i as f32).collect();
        let vrow: Vec<f32> = (0..d).map(|i| -(i as f32)).collect();
        a.write_row(1, p, 2, &krow, &vrow);
        let mut scratch = Vec::new();
        let blk = a.read_block(Plane::K, 1, p, 3, &mut scratch);
        assert_eq!(&blk[2 * d..3 * d], &krow[..]);
        let blk = a.read_block(Plane::V, 1, p, 3, &mut scratch);
        assert_eq!(&blk[2 * d..3 * d], &vrow[..]);
    }

    #[test]
    fn copy_rows_copies_prefix_all_layers() {
        let cfg = NativeConfig::named("nano").unwrap();
        let d = cfg.d_model;
        let mut a = BlockAllocator::new(&cfg, 2, 4);
        let src = a.alloc().unwrap();
        let dst = a.alloc().unwrap();
        for li in 0..cfg.n_layers {
            for s in 0..4 {
                let row = vec![(li * 10 + s) as f32; d];
                a.write_row(li, src, s, &row, &row);
            }
        }
        a.copy_rows(src, dst, 3);
        let mut scratch = Vec::new();
        for li in 0..cfg.n_layers {
            let blk = a.read_block(Plane::K, li, dst, 3, &mut scratch);
            for s in 0..3 {
                assert_eq!(blk[s * d], (li * 10 + s) as f32);
            }
        }
    }

    #[test]
    fn peak_used_tracks_high_water() {
        let mut a = arena(3);
        let p = a.alloc().unwrap();
        let q = a.alloc().unwrap();
        a.release(p);
        a.release(q);
        let _r = a.alloc().unwrap();
        assert_eq!(a.peak_used(), 2);
    }

    #[test]
    fn lease_counts_gate_tile_admission_through_the_allocator() {
        // Allocator-driven stores admit frozen tiles only once ≥ 2
        // sequences lease the page on top of the index's reference.
        let cfg = NativeConfig::named("nano").unwrap();
        let d = cfg.d_model;
        let mut a = BlockAllocator::new_with(&cfg, 2, 2, KvDtype::Int8);
        let p = a.alloc().unwrap();
        for s in 0..2 {
            a.write_row(0, p, s, &vec![1.0; d], &vec![1.0; d]);
        }
        a.freeze_page(p);
        // refs = 1 (the index alone): zero reader leases → not cached.
        assert!(a.store().frozen_tile(Plane::V, 0, p).is_some());
        assert!(a.store().frozen_tile(Plane::V, 0, p).is_some());
        assert_eq!(a.store().tile_cache_stats(), (0, 2), "single-reader tile never admitted");
        // Two readers lease on top of the index reference → admitted.
        a.retain(p);
        a.retain(p);
        assert!(a.store().frozen_tile(Plane::V, 0, p).is_some());
        assert!(a.store().frozen_tile(Plane::V, 0, p).is_some());
        assert_eq!(a.store().tile_cache_stats(), (1, 3), "admitted on miss 3, hit on access 4");
    }

    #[test]
    fn int8_arena_reads_back_within_quantum() {
        let cfg = NativeConfig::named("nano").unwrap();
        let d = cfg.d_model;
        let mut a = BlockAllocator::new_with(&cfg, 2, 4, KvDtype::Int8);
        assert_eq!(a.dtype(), KvDtype::Int8);
        let p = a.alloc().unwrap();
        let krow: Vec<f32> = (0..d).map(|i| (i as f32 - 60.0) * 0.01).collect();
        a.write_row(0, p, 0, &krow, &krow);
        let mut scratch = Vec::new();
        let blk = a.read_block(Plane::K, 0, p, 1, &mut scratch);
        for (x, y) in blk.iter().zip(&krow) {
            assert!((x - y).abs() <= 0.02, "{x} vs {y}");
        }
        assert!(a.bytes() * 2 <= BlockAllocator::new(&cfg, 2, 4).bytes());
    }

    #[test]
    fn realloc_resets_quantizer_state() {
        // A page freed and re-allocated must not inherit the old scale:
        // a small row on the fresh page gets full resolution.
        let cfg = NativeConfig::named("nano").unwrap();
        let d = cfg.d_model;
        let mut a = BlockAllocator::new_with(&cfg, 1, 2, KvDtype::Int8);
        let p = a.alloc().unwrap();
        a.write_row(0, p, 0, &vec![1000.0; d], &vec![1000.0; d]);
        a.release(p);
        let p2 = a.alloc().unwrap();
        assert_eq!(p, p2, "single-page arena reuses the page");
        let tiny = vec![0.001; d];
        a.write_row(0, p2, 0, &tiny, &tiny);
        let mut scratch = Vec::new();
        let blk = a.read_block(Plane::K, 0, p2, 1, &mut scratch);
        assert!((blk[0] - 0.001).abs() < 1e-5, "fresh scale, not the stale 1000-range one");
    }
}
