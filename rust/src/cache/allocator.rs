//! Fixed-page block allocator over one preallocated per-layer K/V arena.
//!
//! The paper's Limitations flag the BF16 KV cache as the dominant
//! transient memory on edge devices; the seed design leased whole
//! `seq_len`-sized contiguous caches, so admission was capped by
//! worst-case allocation. Here KV memory is a single arena per layer,
//! carved into fixed pages of `page_size` positions. Sequences map
//! logical positions onto pages through a [`BlockTable`]
//! (`super::table`); pages are refcounted so a frozen prompt prefix can
//! back any number of sequences at once (radix sharing, `super::prefix`).
//!
//! [`BlockTable`]: super::table::BlockTable

use crate::engine::NativeConfig;

/// Index of a page in the arena.
pub type PageId = u32;

/// Refcounted fixed-page arena for K and V, one plane per layer.
///
/// Layout: page `p`, slot `s` (position within the page), channel `c`
/// live at `arena[layer][(p * page_size + s) * d_model + c]`. Pages are
/// never zeroed on (re)allocation — a slot is always written before any
/// read reaches it because attention reads only positions `< len`.
pub struct BlockAllocator {
    page_size: usize,
    d_model: usize,
    n_layers: usize,
    num_pages: usize,
    /// Per-layer K arenas: `num_pages * page_size * d_model` floats.
    k: Vec<Vec<f32>>,
    /// Per-layer V arenas, same shape.
    v: Vec<Vec<f32>>,
    /// Per-page reference counts (0 = free).
    refs: Vec<u32>,
    /// Free-page stack.
    free: Vec<PageId>,
    peak_used: usize,
}

impl BlockAllocator {
    /// Arena with `num_pages` pages of `page_size` positions each, shaped
    /// for `cfg` (one K and one V plane per layer).
    pub fn new(cfg: &NativeConfig, num_pages: usize, page_size: usize) -> Self {
        assert!(num_pages > 0 && page_size > 0, "arena must hold at least one slot");
        assert!(num_pages <= PageId::MAX as usize, "page id space exhausted");
        let plane = num_pages * page_size * cfg.d_model;
        Self {
            page_size,
            d_model: cfg.d_model,
            n_layers: cfg.n_layers,
            num_pages,
            k: (0..cfg.n_layers).map(|_| vec![0.0; plane]).collect(),
            v: (0..cfg.n_layers).map(|_| vec![0.0; plane]).collect(),
            refs: vec![0; num_pages],
            // Pop order is descending ids; purely cosmetic.
            free: (0..num_pages as PageId).rev().collect(),
            peak_used: 0,
        }
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    pub fn d_model(&self) -> usize {
        self.d_model
    }

    pub fn num_pages(&self) -> usize {
        self.num_pages
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn used_pages(&self) -> usize {
        self.num_pages - self.free.len()
    }

    /// High-water mark of pages in use (block-utilization gauge).
    pub fn peak_used(&self) -> usize {
        self.peak_used
    }

    /// Current reference count of `p` (0 = free).
    pub fn ref_count(&self, p: PageId) -> u32 {
        self.refs[p as usize]
    }

    /// Total arena bytes (KV byte budget, at the 4 B/f32 storage width the
    /// engine uses — see DESIGN.md substitutions for the bf16 accounting).
    pub fn bytes(&self) -> usize {
        2 * self.n_layers * self.num_pages * self.page_size * self.d_model * 4
    }

    /// Take a free page with refcount 1, or `None` when the arena is full.
    pub fn alloc(&mut self) -> Option<PageId> {
        let p = self.free.pop()?;
        debug_assert_eq!(self.refs[p as usize], 0, "free page with live refs");
        self.refs[p as usize] = 1;
        self.peak_used = self.peak_used.max(self.used_pages());
        Some(p)
    }

    /// Add a reference to a live page (prefix sharing).
    pub fn retain(&mut self, p: PageId) {
        assert!(self.refs[p as usize] > 0, "retain of a free page");
        self.refs[p as usize] += 1;
    }

    /// Drop one reference; the page returns to the free stack at zero.
    pub fn release(&mut self, p: PageId) {
        let r = &mut self.refs[p as usize];
        assert!(*r > 0, "double free of page {p}");
        *r -= 1;
        if *r == 0 {
            self.free.push(p);
        }
    }

    /// Write one position's K and V rows into `(page, slot)` of `layer`.
    #[inline]
    pub fn write_row(
        &mut self,
        layer: usize,
        p: PageId,
        slot: usize,
        k_row: &[f32],
        v_row: &[f32],
    ) {
        debug_assert!(slot < self.page_size);
        debug_assert!(self.refs[p as usize] > 0, "write to a free page");
        let d = self.d_model;
        let base = (p as usize * self.page_size + slot) * d;
        self.k[layer][base..base + d].copy_from_slice(k_row);
        self.v[layer][base..base + d].copy_from_slice(v_row);
    }

    /// The whole K plane of `layer` (attention reads through
    /// [`Rows`](super::view::Rows), which indexes pages into this slab).
    #[inline]
    pub fn k_plane(&self, layer: usize) -> &[f32] {
        &self.k[layer]
    }

    /// The whole V plane of `layer`.
    #[inline]
    pub fn v_plane(&self, layer: usize) -> &[f32] {
        &self.v[layer]
    }

    /// Copy the first `rows` slots of `src` into `dst` across every layer
    /// (copy-on-write: the diverging sequence gets a private copy of the
    /// shared page's prefix; `src` itself is never written).
    pub fn copy_rows(&mut self, src: PageId, dst: PageId, rows: usize) {
        debug_assert!(rows <= self.page_size);
        debug_assert_ne!(src, dst, "CoW onto the same page");
        let d = self.d_model;
        let n = rows * d;
        let (s0, d0) = (src as usize * self.page_size * d, dst as usize * self.page_size * d);
        for li in 0..self.n_layers {
            let (k0, v0) = (&mut self.k[li], &mut self.v[li]);
            k0.copy_within(s0..s0 + n, d0);
            v0.copy_within(s0..s0 + n, d0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arena(pages: usize) -> BlockAllocator {
        BlockAllocator::new(&NativeConfig::named("nano").unwrap(), pages, 4)
    }

    #[test]
    fn alloc_release_roundtrip() {
        let mut a = arena(3);
        assert_eq!(a.free_pages(), 3);
        let p = a.alloc().unwrap();
        assert_eq!(a.ref_count(p), 1);
        assert_eq!(a.used_pages(), 1);
        a.release(p);
        assert_eq!(a.free_pages(), 3);
        assert_eq!(a.ref_count(p), 0);
    }

    #[test]
    fn exhaustion_returns_none() {
        let mut a = arena(2);
        let _p = a.alloc().unwrap();
        let _q = a.alloc().unwrap();
        assert!(a.alloc().is_none());
    }

    #[test]
    fn retain_keeps_page_alive() {
        let mut a = arena(2);
        let p = a.alloc().unwrap();
        a.retain(p);
        a.release(p);
        assert_eq!(a.ref_count(p), 1, "still referenced");
        assert_eq!(a.used_pages(), 1);
        a.release(p);
        assert_eq!(a.used_pages(), 0);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut a = arena(2);
        let p = a.alloc().unwrap();
        a.release(p);
        a.release(p);
    }

    #[test]
    fn rows_written_are_read_back() {
        let cfg = NativeConfig::named("nano").unwrap();
        let d = cfg.d_model;
        let mut a = BlockAllocator::new(&cfg, 2, 4);
        let p = a.alloc().unwrap();
        let krow: Vec<f32> = (0..d).map(|i| i as f32).collect();
        let vrow: Vec<f32> = (0..d).map(|i| -(i as f32)).collect();
        a.write_row(1, p, 2, &krow, &vrow);
        let base = (p as usize * 4 + 2) * d;
        assert_eq!(&a.k_plane(1)[base..base + d], &krow[..]);
        assert_eq!(&a.v_plane(1)[base..base + d], &vrow[..]);
    }

    #[test]
    fn copy_rows_copies_prefix_all_layers() {
        let cfg = NativeConfig::named("nano").unwrap();
        let d = cfg.d_model;
        let mut a = BlockAllocator::new(&cfg, 2, 4);
        let src = a.alloc().unwrap();
        let dst = a.alloc().unwrap();
        for li in 0..cfg.n_layers {
            for s in 0..4 {
                let row = vec![(li * 10 + s) as f32; d];
                a.write_row(li, src, s, &row, &row);
            }
        }
        a.copy_rows(src, dst, 3);
        for li in 0..cfg.n_layers {
            for s in 0..3 {
                let base = (dst as usize * 4 + s) * d;
                assert_eq!(a.k_plane(li)[base], (li * 10 + s) as f32);
            }
        }
    }

    #[test]
    fn peak_used_tracks_high_water() {
        let mut a = arena(3);
        let p = a.alloc().unwrap();
        let q = a.alloc().unwrap();
        a.release(p);
        a.release(q);
        let _r = a.alloc().unwrap();
        assert_eq!(a.peak_used(), 2);
    }
}
