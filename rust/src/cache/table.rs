//! Per-sequence block table: maps logical KV positions onto arena pages.
//!
//! A table starts either empty or seeded with refcounted pages borrowed
//! from the radix prefix index (`super::prefix`). Shared pages are
//! frozen: the first append that would land inside one triggers
//! copy-on-write, so a diverging sequence can never mutate KV rows
//! another sequence (or the index) still reads.

use super::allocator::{BlockAllocator, PageId};

/// Logical-position → page mapping for one sequence.
pub struct BlockTable {
    page_size: usize,
    pages: Vec<PageId>,
    /// Positions stored (the sequence's KV length).
    len: usize,
    /// Pages `[0, owned_from)` are shared/frozen (prefix-index pages this
    /// table only holds a reference to); pages from `owned_from` on are
    /// exclusively owned and writable.
    owned_from: usize,
    /// Pages this table allocated itself (fresh allocs + CoW copies) —
    /// admission accounting subtracts this from the pessimistic
    /// reservation to get outstanding future allocations.
    owned: usize,
}

impl BlockTable {
    /// Empty table (no shared prefix).
    pub fn new(page_size: usize) -> Self {
        assert!(page_size > 0);
        Self { page_size, pages: Vec::new(), len: 0, owned_from: 0, owned: 0 }
    }

    /// Table seeded with `shared_len` positions backed by frozen `pages`
    /// from the prefix index. The caller has already taken one reference
    /// per page; this table releases them via [`BlockTable::release_all`].
    pub fn from_shared(page_size: usize, pages: Vec<PageId>, shared_len: usize) -> Self {
        assert!(page_size > 0);
        assert_eq!(pages.len(), shared_len.div_ceil(page_size), "pages must cover shared span");
        let owned_from = pages.len();
        Self { page_size, pages, len: shared_len, owned_from, owned: 0 }
    }

    /// Positions stored.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn pages(&self) -> &[PageId] {
        &self.pages
    }

    /// Pages this table allocated itself (excludes shared prefix pages).
    pub fn owned_pages(&self) -> usize {
        self.owned
    }

    /// Number of leading positions still backed by frozen shared pages.
    pub fn shared_prefix_pages(&self) -> usize {
        self.owned_from
    }

    /// Make the slot for position `self.len()` writable: allocates a
    /// fresh page at a page boundary, copy-on-writes the tail page if it
    /// is shared, and is a no-op when the tail page is already owned.
    /// Must be called once before the first [`BlockTable::slot_for`]
    /// write of each appended position.
    ///
    /// Panics when the arena is out of pages — the coordinator's
    /// admission control reserves pages pessimistically, so exhaustion
    /// here is a scheduling bug, not a load condition.
    pub fn prepare_append(&mut self, alloc: &mut BlockAllocator) {
        debug_assert_eq!(self.page_size, alloc.page_size(), "table/arena page size mismatch");
        let pi = self.len / self.page_size;
        if pi == self.pages.len() {
            let p = alloc
                .alloc()
                .expect("KV arena exhausted: admission must reserve pages before activation");
            self.pages.push(p);
            self.owned += 1;
        } else if pi < self.owned_from {
            // First divergence into a shared page: copy its live prefix
            // into a private page, drop our reference to the shared one.
            // The copy goes through the PageStore, so quantized stores
            // carry their per-page quantizer state with the bytes.
            debug_assert_eq!(pi + 1, self.pages.len(), "append can only CoW the tail page");
            let src = self.pages[pi];
            let dst = alloc
                .alloc()
                .expect("KV arena exhausted: admission must reserve the CoW page");
            alloc.copy_rows(src, dst, self.len % self.page_size);
            alloc.release(src);
            self.pages[pi] = dst;
            self.owned_from = pi;
            self.owned += 1;
        }
    }

    /// `(page, slot)` backing logical position `pos` (`pos < len`, or
    /// `pos == len` after [`BlockTable::prepare_append`]).
    #[inline]
    pub fn slot_for(&self, pos: usize) -> (PageId, usize) {
        (self.pages[pos / self.page_size], pos % self.page_size)
    }

    /// Commit one appended position.
    pub fn advance(&mut self) {
        self.len += 1;
        let cap = self.pages.len() * self.page_size;
        debug_assert!(self.len <= cap, "advance before prepare_append");
    }

    /// Drop every page reference this table holds (sequence retirement).
    pub fn release_all(&mut self, alloc: &mut BlockAllocator) {
        for p in self.pages.drain(..) {
            alloc.release(p);
        }
        self.len = 0;
        self.owned_from = 0;
        self.owned = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::NativeConfig;

    fn arena(pages: usize, ps: usize) -> BlockAllocator {
        BlockAllocator::new(&NativeConfig::named("nano").unwrap(), pages, ps)
    }

    #[test]
    fn grows_one_page_per_page_size_positions() {
        let mut a = arena(4, 4);
        let mut t = BlockTable::new(4);
        for pos in 0..9 {
            t.prepare_append(&mut a);
            let (_, slot) = t.slot_for(pos);
            assert_eq!(slot, pos % 4);
            t.advance();
        }
        assert_eq!(t.len(), 9);
        assert_eq!(t.pages().len(), 3);
        assert_eq!(t.owned_pages(), 3);
        assert_eq!(a.used_pages(), 3);
        t.release_all(&mut a);
        assert_eq!(a.used_pages(), 0);
    }

    #[test]
    fn cow_on_first_divergence_into_partial_shared_page() {
        let cfg = NativeConfig::named("nano").unwrap();
        let d = cfg.d_model;
        let mut a = arena(4, 4);
        // Donor fills one full page (4 positions).
        let shared = a.alloc().unwrap();
        for s in 0..4 {
            let row = vec![s as f32; d];
            a.write_row(0, shared, s, &row, &row);
        }
        // Recipient shares the first 3 positions of that page.
        a.retain(shared);
        let mut t = BlockTable::from_shared(4, vec![shared], 3);
        assert_eq!(t.shared_prefix_pages(), 1);
        let mut scratch = Vec::new();
        let snapshot: Vec<f32> =
            a.read_block(crate::cache::Plane::K, 0, shared, 4, &mut scratch).to_vec();

        // Appending position 3 diverges inside the shared page → CoW.
        t.prepare_append(&mut a);
        let (p, slot) = t.slot_for(3);
        assert_ne!(p, shared, "divergence must land on a private copy");
        assert_eq!(slot, 3);
        assert_eq!(t.shared_prefix_pages(), 0);
        assert_eq!(t.owned_pages(), 1);
        let row = vec![99.0; d];
        a.write_row(0, p, slot, &row, &row);
        t.advance();

        // The shared page is bit-identical to before the divergence …
        assert_eq!(
            a.read_block(crate::cache::Plane::K, 0, shared, 4, &mut scratch),
            &snapshot[..]
        );
        // … and the copy carried the live prefix over.
        let copy: Vec<f32> =
            a.read_block(crate::cache::Plane::K, 0, p, 4, &mut scratch).to_vec();
        assert_eq!(copy[0], 0.0);
        assert_eq!(copy[2 * d], 2.0);
        assert_eq!(copy[3 * d], 99.0);
        // Our reference moved from the shared page to the copy.
        assert_eq!(a.ref_count(shared), 1);

        t.release_all(&mut a);
        a.release(shared);
        assert_eq!(a.used_pages(), 0);
    }

    #[test]
    fn fully_shared_pages_never_cow() {
        let mut a = arena(4, 4);
        let shared = a.alloc().unwrap();
        a.retain(shared);
        // Shared span ends exactly at the page boundary.
        let mut t = BlockTable::from_shared(4, vec![shared], 4);
        t.prepare_append(&mut a);
        let (p, slot) = t.slot_for(4);
        assert_ne!(p, shared);
        assert_eq!(slot, 0, "append starts a fresh page");
        assert_eq!(t.shared_prefix_pages(), 1, "full page stays shared");
        t.advance();
        t.release_all(&mut a);
        assert_eq!(a.ref_count(shared), 1);
        a.release(shared);
    }
}
