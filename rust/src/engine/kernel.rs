//! The unified `TernaryKernel` trait: one dispatch surface for every
//! weight format the engine can serve (Sherry 3:4, TL2, I2_S, dense f32).
//!
//! This replaces the three parallel dispatch mechanisms the engine grew up
//! with (a `Weights` enum in the linear layer, a `Box<dyn PackedMatrix>`
//! factory in `pack/`, and per-format free functions). A kernel exposes
//! two entry points:
//!
//! * [`TernaryKernel::gemv`] — single-row y = W·x (the classic decode
//!   path);
//! * [`TernaryKernel::gemm_nt`] — batched Y = X·Wᵀ over `batch` activation
//!   rows: all activation LUTs are built **up front**, then one pass over
//!   the packed weight planes indexes every row's LUT, parallelized over
//!   output-channel tiles on the shared [`ThreadPool`]. This is what turns
//!   the continuous batcher's decode round into a single fused mpGEMM per
//!   layer instead of `batch` independent GEMVs.
//!
//! Implementations provide three primitives — `lut_len` / `build_luts` /
//! `gemm_tile` — and inherit both entry points, which therefore share one
//! code path: batched and single-row execution are bit-for-bit identical
//! per (row, channel) by construction (asserted by the parity tests
//! below). See DESIGN.md §Kernel for the tiling scheme.

use crate::engine::lut;
use crate::pack::{Packed34, PackedI2S, PackedTl2};
use crate::tensor::gemv_f32;
use crate::util::ThreadPool;

/// Output channels per parallel tile of [`TernaryKernel::gemm_nt`]. Small
/// enough for load balance on wide layers, large enough that the per-tile
/// LUT walk amortizes the spawn overhead.
const GEMM_TILE_J: usize = 64;

/// Reusable LUT scratch for the kernels (one per worker/caller context).
///
/// One buffer serves every format: a layer claims exactly the length it
/// needs via [`Scratch::lut_buf`]. The returned slice is **explicitly
/// truncated to the claim**, so a stale tail from a larger layer's claim
/// is unreachable through the slice. *Within* the claim, correctness
/// rests on the builder-totality contract — every builder overwrites
/// every entry of the region it claims (`build_luts34` writes all 16
/// entries per block; `build_luts_tl2` zeroes its padding lanes 27..32
/// per group; pinned by `tl2_builder_fully_owns_its_region`) — because
/// reused capacity is NOT re-zeroed per claim: claim sizes alternate
/// between the d_model- and d_ff-shaped layers every few calls, so a
/// per-claim memset (or any zero-on-size-change memo) would burn
/// bandwidth in the decode hot path for lanes the kernels never read.
/// A new format whose builder skips entries must zero them itself.
#[derive(Default, Clone)]
pub struct Scratch {
    luts: Vec<f32>,
}

impl Scratch {
    /// Claim a LUT buffer of exactly `need` floats.
    pub fn lut_buf(&mut self, need: usize) -> &mut [f32] {
        if self.luts.len() < need {
            self.luts.resize(need, 0.0); // growth arrives zeroed
        }
        &mut self.luts[..need]
    }
}

/// Shared mutable output pointer for the tile fan-out. Tiles write
/// disjoint channel ranges, so handing each tile its own `&mut` sub-slice
/// derived from this pointer is sound (same contract as `chunks_mut`,
/// just strided per batch row).
#[derive(Clone, Copy)]
struct OutPtr(*mut f32);

unsafe impl Send for OutPtr {}
unsafe impl Sync for OutPtr {}

/// A packed (or dense) weight matrix plus the kernel that multiplies it.
///
/// Shapes follow the engine convention: `d_out` output channels ×
/// `d_in` inputs, activations as flat `f32` rows.
pub trait TernaryKernel: Send + Sync {
    /// Number of input features.
    fn d_in(&self) -> usize;

    /// Number of output channels.
    fn d_out(&self) -> usize;

    /// Bytes of the stored weight planes (size accounting for Table 4;
    /// excludes per-channel scales).
    fn weight_bytes(&self) -> usize;

    /// f32 scratch entries one activation row's lookup tables occupy
    /// (0 for LUT-free formats).
    fn lut_len(&self) -> usize;

    /// Build one activation row's tables into `luts`
    /// (`luts.len() == self.lut_len()`). No-op for LUT-free formats.
    fn build_luts(&self, x: &[f32], luts: &mut [f32]);

    /// Accumulate output channels `[j0, j1)` for `batch` rows.
    ///
    /// `xs` is `batch × d_in`; `luts` holds the prebuilt tables at stride
    /// `lut_len()` per row (empty for LUT-free formats, which read `xs`
    /// directly); `out` is `batch × (j1-j0)` row-major with per-channel α
    /// already applied.
    fn gemm_tile(&self, xs: &[f32], luts: &[f32], batch: usize, j0: usize, j1: usize, out: &mut [f32]);

    /// Single-row y = W·x. Same code path as [`TernaryKernel::gemm_nt`]
    /// with `batch = 1`.
    fn gemv(&self, x: &[f32], y: &mut [f32], scratch: &mut Scratch) {
        assert_eq!(x.len(), self.d_in());
        assert_eq!(y.len(), self.d_out());
        let luts = scratch.lut_buf(self.lut_len());
        self.build_luts(x, luts);
        self.gemm_tile(x, luts, 1, 0, self.d_out(), y);
    }

    /// Batched Y = X·Wᵀ: `xs` is `batch × d_in` row-major, `ys` is
    /// `batch × d_out` row-major.
    ///
    /// Phase 1 builds all `batch` activation LUTs up front in `scratch`;
    /// phase 2 makes one pass over the packed weight planes with every
    /// LUT resident, tiled over output channels and fanned out on `pool`
    /// (`None`, or a narrow layer, runs the single full-width tile
    /// inline). Tile boundaries never change results: channels are
    /// independent and per-(row, channel) accumulation order is fixed by
    /// `gemm_tile`.
    fn gemm_nt(
        &self,
        xs: &[f32],
        ys: &mut [f32],
        batch: usize,
        scratch: &mut Scratch,
        pool: Option<&ThreadPool>,
    ) {
        let (d_in, d_out) = (self.d_in(), self.d_out());
        assert_eq!(xs.len(), batch * d_in, "xs must be batch × d_in");
        assert_eq!(ys.len(), batch * d_out, "ys must be batch × d_out");
        if batch == 0 || d_out == 0 {
            return;
        }
        let ll = self.lut_len();
        let luts = scratch.lut_buf(ll * batch);
        for bi in 0..batch {
            self.build_luts(&xs[bi * d_in..(bi + 1) * d_in], &mut luts[bi * ll..(bi + 1) * ll]);
        }
        let luts: &[f32] = luts;
        match pool {
            Some(pool) if d_out > GEMM_TILE_J => {
                let n_tiles = d_out.div_ceil(GEMM_TILE_J);
                let out = OutPtr(ys.as_mut_ptr());
                pool.par_for(n_tiles, |t| {
                    let j0 = t * GEMM_TILE_J;
                    let j1 = (j0 + GEMM_TILE_J).min(d_out);
                    let w = j1 - j0;
                    // One small alloc per tile job, amortized over the
                    // batch × tile_width × d_in accumulate below (the
                    // serial/B=1 paths below and in gemv are alloc-free).
                    let mut tile = vec![0.0f32; batch * w];
                    self.gemm_tile(xs, luts, batch, j0, j1, &mut tile);
                    for bi in 0..batch {
                        // SAFETY: tiles partition [0, d_out) disjointly, so
                        // each (row, tile) destination slice is disjoint
                        // from every other tile's writes, and the borrow of
                        // `ys` is held (unused) across the scoped fan-out.
                        let dst = unsafe {
                            std::slice::from_raw_parts_mut(out.0.add(bi * d_out + j0), w)
                        };
                        dst.copy_from_slice(&tile[bi * w..(bi + 1) * w]);
                    }
                });
            }
            _ => {
                // One full-width tile: `ys`'s batch-major layout is exactly
                // the tile layout at (j0, j1) = (0, d_out).
                self.gemm_tile(xs, luts, batch, 0, d_out, ys);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Format implementations
// ---------------------------------------------------------------------------

impl TernaryKernel for Packed34 {
    fn d_in(&self) -> usize {
        self.d_in
    }

    fn d_out(&self) -> usize {
        self.d_out
    }

    fn weight_bytes(&self) -> usize {
        Packed34::weight_bytes(self)
    }

    fn lut_len(&self) -> usize {
        (self.d_in / 4) * 16
    }

    fn build_luts(&self, x: &[f32], luts: &mut [f32]) {
        lut::build_luts34(x, luts);
    }

    fn gemm_tile(&self, _xs: &[f32], luts: &[f32], batch: usize, j0: usize, j1: usize, out: &mut [f32]) {
        crate::simd::gemm_pack34_preluts(self, luts, TernaryKernel::lut_len(self), batch, j0, j1, out);
    }
}

impl TernaryKernel for PackedTl2 {
    fn d_in(&self) -> usize {
        self.d_in
    }

    fn d_out(&self) -> usize {
        self.d_out
    }

    fn weight_bytes(&self) -> usize {
        PackedTl2::weight_bytes(self)
    }

    fn lut_len(&self) -> usize {
        self.n_groups() * lut::TL2_LUT_STRIDE
    }

    fn build_luts(&self, x: &[f32], luts: &mut [f32]) {
        lut::build_luts_tl2(x, luts);
    }

    fn gemm_tile(&self, _xs: &[f32], luts: &[f32], batch: usize, j0: usize, j1: usize, out: &mut [f32]) {
        crate::simd::gemm_tl2_preluts(self, luts, TernaryKernel::lut_len(self), batch, j0, j1, out);
    }
}

impl TernaryKernel for PackedI2S {
    fn d_in(&self) -> usize {
        self.d_in
    }

    fn d_out(&self) -> usize {
        self.d_out
    }

    fn weight_bytes(&self) -> usize {
        PackedI2S::weight_bytes(self)
    }

    fn lut_len(&self) -> usize {
        0 // decode-and-add: no activation preprocessing
    }

    fn build_luts(&self, _x: &[f32], _luts: &mut [f32]) {}

    fn gemm_tile(&self, xs: &[f32], _luts: &[f32], batch: usize, j0: usize, j1: usize, out: &mut [f32]) {
        crate::simd::gemm_i2s(self, xs, batch, j0, j1, out);
    }
}

/// Dense f32 kernel — the BF16-stand-in baseline, behind the same trait so
/// the engine has exactly one dispatch path.
pub struct DenseKernel {
    d_in: usize,
    d_out: usize,
    /// `d_out × d_in` row-major (GEMV iteration order).
    w: Vec<f32>,
}

impl DenseKernel {
    /// From a `d_out × d_in` row-major buffer.
    pub fn from_rows(d_in: usize, d_out: usize, w: Vec<f32>) -> Self {
        assert_eq!(w.len(), d_in * d_out);
        Self { d_in, d_out, w }
    }
}

impl TernaryKernel for DenseKernel {
    fn d_in(&self) -> usize {
        self.d_in
    }

    fn d_out(&self) -> usize {
        self.d_out
    }

    fn weight_bytes(&self) -> usize {
        // Accounted as bf16 (the paper's baseline precision; stored f32 —
        // see DESIGN.md substitutions).
        self.w.len() * 2
    }

    fn lut_len(&self) -> usize {
        0
    }

    fn build_luts(&self, _x: &[f32], _luts: &mut [f32]) {}

    fn gemm_tile(&self, xs: &[f32], _luts: &[f32], batch: usize, j0: usize, j1: usize, out: &mut [f32]) {
        assert!(j0 <= j1 && j1 <= self.d_out);
        let w = j1 - j0;
        assert_eq!(xs.len(), batch * self.d_in);
        assert_eq!(out.len(), batch * w);
        // Rows j0..j1 are contiguous in the row-major weight buffer, and a
        // batch row's tile output is the contiguous channel range — so each
        // batch row is one literal ops::gemv_f32 call over the sub-matrix:
        // batched and single dense paths share its accumulation order by
        // construction (not by copy-paste).
        let rows = &self.w[j0 * self.d_in..j1 * self.d_in];
        for bi in 0..batch {
            let x = &xs[bi * self.d_in..(bi + 1) * self.d_in];
            gemv_f32(rows, w, self.d_in, x, &mut out[bi * w..(bi + 1) * w]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quantize, Granularity, Method};
    use crate::tensor::Mat;
    use crate::util::Pcg64;

    #[allow(clippy::type_complexity)]
    fn kernels(d_in: usize, d_out: usize, seed: u64) -> Vec<(&'static str, Box<dyn TernaryKernel>)> {
        let mut rng = Pcg64::seeded(seed);
        let w = Mat::randn(&mut rng, d_in, d_out, 1.0);
        let qs = quantize(&w, Method::Sherry34, Granularity::PerChannel);
        let qd = quantize(&w, Method::AbsMean, Granularity::PerChannel);
        vec![
            ("sherry", Box::new(Packed34::from_ternary(&qs))),
            ("tl2", Box::new(PackedTl2::from_ternary(&qd))),
            ("i2_s", Box::new(PackedI2S::from_ternary(&qd))),
            ("dense", Box::new(DenseKernel::from_rows(d_in, d_out, w.transpose().data))),
        ]
    }

    /// Acceptance: for every format, `gemm_nt` with B=16 produces outputs
    /// identical (bit-for-bit) to 16 independent `gemv` calls — with and
    /// without the thread-pool fan-out.
    #[test]
    fn gemm_nt_matches_16_independent_gemvs_bit_for_bit() {
        let (d_in, d_out, b) = (128usize, 96usize, 16usize);
        let pool = ThreadPool::new(4);
        for (name, k) in kernels(d_in, d_out, 0) {
            let mut rng = Pcg64::seeded(1);
            let xs = rng.normal_vec(b * d_in);
            let mut singles = vec![0.0f32; b * d_out];
            let mut scratch = Scratch::default();
            for bi in 0..b {
                let (x, y) = (
                    &xs[bi * d_in..(bi + 1) * d_in],
                    &mut singles[bi * d_out..(bi + 1) * d_out],
                );
                k.gemv(x, y, &mut scratch);
            }
            for pool_opt in [None, Some(&pool)] {
                let mut batched = vec![0.0f32; b * d_out];
                let mut scratch_b = Scratch::default();
                k.gemm_nt(&xs, &mut batched, b, &mut scratch_b, pool_opt);
                for (i, (a, s)) in batched.iter().zip(&singles).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        s.to_bits(),
                        "{name} (pool={}) row {} ch {}: {a} vs {s}",
                        pool_opt.is_some(),
                        i / d_out,
                        i % d_out
                    );
                }
            }
        }
    }

    /// The batched path must also hold on shapes that exercise the channel
    /// tiling (d_out > GEMM_TILE_J), k-tiling tails (d_in % 32 != 0 for
    /// pack34), and TL2's padded groups (d_in % 3 != 0).
    #[test]
    fn gemm_nt_parity_on_ragged_shapes() {
        let pool = ThreadPool::new(3);
        for &(d_in, d_out, b) in &[(36usize, 200usize, 5usize), (100, 70, 2), (388, 130, 4)] {
            for (name, k) in kernels(d_in, d_out, d_in as u64) {
                let mut rng = Pcg64::seeded(2);
                let xs = rng.normal_vec(b * d_in);
                let mut singles = vec![0.0f32; b * d_out];
                let mut scratch = Scratch::default();
                for bi in 0..b {
                    let ys = &mut singles[bi * d_out..(bi + 1) * d_out];
                    k.gemv(&xs[bi * d_in..(bi + 1) * d_in], ys, &mut scratch);
                }
                let mut batched = vec![0.0f32; b * d_out];
                k.gemm_nt(&xs, &mut batched, b, &mut scratch, Some(&pool));
                for (a, s) in batched.iter().zip(&singles) {
                    assert_eq!(a.to_bits(), s.to_bits(), "{name} {d_in}x{d_out} b={b}");
                }
            }
        }
    }

    #[test]
    fn gemm_nt_matches_dense_reference() {
        // Correctness (not just self-consistency): batched LUT output must
        // match the dequantized dense product.
        let (d_in, d_out, b) = (256usize, 48usize, 4usize);
        let mut rng = Pcg64::seeded(3);
        let w = Mat::randn(&mut rng, d_in, d_out, 1.0);
        let q = quantize(&w, Method::Sherry34, Granularity::PerChannel);
        let k = Packed34::from_ternary(&q);
        let xs = rng.normal_vec(b * d_in);
        let mut ys = vec![0.0f32; b * d_out];
        let mut scratch = Scratch::default();
        k.gemm_nt(&xs, &mut ys, b, &mut scratch, None);
        let wt = q.dequant().transpose();
        for bi in 0..b {
            let mut y_ref = vec![0.0f32; d_out];
            crate::tensor::gemv_f32(&wt.data, d_out, d_in, &xs[bi * d_in..(bi + 1) * d_in], &mut y_ref);
            for (a, r) in ys[bi * d_out..(bi + 1) * d_out].iter().zip(&y_ref) {
                assert!((a - r).abs() < 1e-3 * (1.0 + r.abs()), "row {bi}: {a} vs {r}");
            }
        }
    }

    #[test]
    fn scratch_truncates_claims_and_zeroes_growth() {
        let mut s = Scratch::default();
        // Dirty a large claim, then shrink: the smaller claim is truncated
        // to exactly the request — the stale tail beyond it is unreachable.
        s.lut_buf(256).fill(7.0);
        assert_eq!(s.lut_buf(64).len(), 64);
        // Growth beyond the previously touched extent arrives zeroed.
        let big = s.lut_buf(512);
        assert_eq!(big.len(), 512);
        assert!(big[256..].iter().all(|&v| v == 0.0), "grown region must be zeroed");
        // Steady-state reuse at a fixed size keeps contents (builders
        // overwrite every entry they own) — this pins the memset-free path.
        s.lut_buf(32).fill(5.0);
        assert!(s.lut_buf(32).iter().all(|&v| v == 5.0));
    }

    #[test]
    fn tl2_builder_fully_owns_its_region() {
        // The stale-tail hazard: a buffer dirtied by a previous (larger)
        // layer must be fully overwritten by the next build — including
        // TL2's padding lanes, the only entries a builder could miss.
        let mut s = Scratch::default();
        s.lut_buf(4 * lut::TL2_LUT_STRIDE).fill(f32::NAN);
        let mut rng = Pcg64::seeded(5);
        let x = rng.normal_vec(9); // 3 groups
        let buf = s.lut_buf(3 * lut::TL2_LUT_STRIDE);
        lut::build_luts_tl2(&x, buf);
        assert!(buf.iter().all(|v| v.is_finite()), "builder left stale entries");
    }

    #[test]
    fn dense_kernel_matches_gemv_f32() {
        let (d_in, d_out) = (77usize, 13usize);
        let mut rng = Pcg64::seeded(4);
        let w = Mat::randn(&mut rng, d_in, d_out, 1.0);
        let k = DenseKernel::from_rows(d_in, d_out, w.transpose().data);
        let x = rng.normal_vec(d_in);
        let mut y = vec![0.0f32; d_out];
        let mut scratch = Scratch::default();
        k.gemv(&x, &mut y, &mut scratch);
        let wt = w.transpose();
        let mut y_ref = vec![0.0f32; d_out];
        crate::tensor::gemv_f32(&wt.data, d_out, d_in, &x, &mut y_ref);
        assert_eq!(y, y_ref);
    }

    #[test]
    fn empty_batch_is_a_noop() {
        for (_name, k) in kernels(64, 32, 9) {
            let mut scratch = Scratch::default();
            k.gemm_nt(&[], &mut [], 0, &mut scratch, None);
        }
    }
}
