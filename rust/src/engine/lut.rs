//! LUT-based multiplication-free GEMV/GEMM kernels (paper Fig. 9, App. A).
//!
//! The engine's two phases:
//! 1. **Activation preprocessing** — for each input segment, precompute a
//!    local lookup table of every possible signed partial sum. The table
//!    is shared across *all* output channels, so its cost amortizes over
//!    d_out — and, in the batched kernels, over the whole batch.
//! 2. **Index-and-accumulate** — per output channel, each packed weight
//!    code directly indexes the segment's table; partial sums accumulate
//!    with pure additions. The only multiply per channel is the final
//!    per-channel scale α.
//!
//! Each format has one *batched range kernel* (`gemm_*`): it accumulates
//! output channels `[j0, j1)` for `batch` activation rows whose LUTs were
//! all built up front, walking each channel's packed weight plane **once**
//! with every row's LUT resident. The packed-code decode cost (the thing
//! Table 4 measures) is thereby amortized ×batch. The single-row `gemv_*`
//! entry points are thin `batch = 1` wrappers, which is what makes
//! batched and single execution bit-for-bit identical: they are the same
//! code path, so per-(row, channel) float accumulation order is equal by
//! construction.
//!
//! * Sherry 1.25-bit — 16-entry LUT per 4-segment, nibble index,
//!   bit-plane mirror sign (power-of-two everything);
//! * TL2 1.67-bit — 27-entry LUT per 3-segment, 5-bit codes pulled
//!   from a misaligned bitstream (the decode tax the paper measures);
//! * I2_S 2-bit — decode-and-add (no LUT, byte aligned).
//!
//! The same trick serves the ternary KV cache's attention score pass:
//! [`build_qk_luts34`] folds one int8-quantized query row into
//! per-(head, block) 32-entry tables and [`qk_lut34_rows`] walks packed
//! 3:4 K pages through them — integer-exact, multiplication-free, and
//! without ever dequantizing K (DESIGN.md §4).

use crate::pack::{Packed34, PackedI2S, PackedTl2};

/// Per-row accumulator slots kept on the stack (2 per row for the
/// dual-accumulator kernels ⇒ 32 rows inline). Only wider batches spill
/// to one heap allocation per range call, so the `batch = 1` gemv path
/// stays allocation-free like the pre-batching kernels.
const ACC_INLINE: usize = 64;

/// Stack-first accumulator storage: borrow `slots` inline slots from
/// `inline`, else allocate into `heap`.
#[inline]
fn acc_storage<'a>(
    inline: &'a mut [f32; ACC_INLINE],
    heap: &'a mut Vec<f32>,
    slots: usize,
) -> &'a mut [f32] {
    if slots <= ACC_INLINE {
        &mut inline[..slots]
    } else {
        heap.resize(slots, 0.0);
        &mut heap[..slots]
    }
}

// ---------------------------------------------------------------------------
// Sherry 1.25-bit kernel
// ---------------------------------------------------------------------------

/// Build the per-block 16-entry tables for the Sherry kernel.
///
/// For block lanes (x0..x3) and zero-lane z, the three active lanes
/// (a, b, c) produce entries `x_a ± x_b ± x_c` at indices
/// `z·4 + (s_b<<1|s_c)`. Computed with 6 adds per z via the
/// sum/difference trick (24 adds per block for all 16 entries).
///
/// `luts` must have length `(x.len()/4) * 16`.
pub fn build_luts34(x: &[f32], luts: &mut [f32]) {
    let nb = x.len() / 4;
    debug_assert_eq!(luts.len(), nb * 16);
    for b in 0..nb {
        let xs = &x[b * 4..b * 4 + 4];
        let out = &mut luts[b * 16..b * 16 + 16];
        for z in 0..4usize {
            // active lanes in increasing order
            let (a, bb, c) = match z {
                0 => (1, 2, 3),
                1 => (0, 2, 3),
                2 => (0, 1, 3),
                _ => (0, 1, 2),
            };
            let base = xs[a];
            let s1 = xs[bb] + xs[c];
            let s2 = xs[bb] - xs[c];
            out[z * 4] = base + s1; // (+, +)
            out[z * 4 + 1] = base + s2; // (+, −)
            out[z * 4 + 2] = base - s2; // (−, +)
            out[z * 4 + 3] = base - s1; // (−, −)
        }
    }
}

/// y = (Packed34 weights) · x, with per-channel α applied.
/// `luts` is caller-provided scratch of length `(d_in/4)*16` so batched
/// callers reuse the allocation; it is (re)filled from `x` here.
pub fn gemv_pack34(p: &Packed34, x: &[f32], luts: &mut [f32], y: &mut [f32]) {
    assert_eq!(x.len(), p.d_in);
    assert_eq!(y.len(), p.d_out);
    build_luts34(x, luts);
    gemv_pack34_preluts(p, luts, y);
}

/// Single-row accumulate phase (tables already built). `batch = 1` case of
/// [`gemm_pack34_preluts`].
pub fn gemv_pack34_preluts(p: &Packed34, luts: &[f32], y: &mut [f32]) {
    gemm_pack34_preluts(p, luts, luts.len(), 1, 0, p.d_out, y);
}

/// Batched accumulate phase over output channels `[j0, j1)`.
///
/// `luts` holds `batch` prebuilt tables at stride `lut_stride`
/// (= `(d_in/4)*16` floats per row); `out` is `batch × (j1-j0)` row-major:
/// `out[bi*(j1-j0) + (j-j0)]` receives yᵦᵢ[j]. Each channel's packed
/// planes are decoded **once** and indexed into every row's table — the
/// weight-plane traversal the batcher amortizes across sequences.
///
/// Perf notes (EXPERIMENTS.md §Perf):
/// * sign application is **branchless** — the mirror bit is shifted into
///   the f32 sign position and XORed (the scalar analogue of the
///   `vpsignb` the paper's AVX2 kernel would use); the naive branch
///   version mispredicted ~50% and ran 0.84 Gw/s;
/// * two accumulators per row hide the add latency chain;
/// * the inner loop walks one sign byte = 8 blocks = 32 weights per
///   iteration, all loads byte-aligned (the point of the 5-bit split
///   into nibble index + sign plane);
/// * cache blocking: the k dimension is walked in tiles of 128 blocks so
///   the active LUT slice (128×16×4 B = 8 KiB per row) stays cache-resident
///   across all channels of the tile; the un-tiled version re-streamed the
///   whole LUT (e.g. 51 KiB at d_in=3200) from L2 once *per channel*.
pub fn gemm_pack34_preluts(
    p: &Packed34,
    luts: &[f32],
    lut_stride: usize,
    batch: usize,
    j0: usize,
    j1: usize,
    out: &mut [f32],
) {
    let nb = p.n_blocks();
    assert!(j0 <= j1 && j1 <= p.d_out);
    let w = j1 - j0;
    assert_eq!(out.len(), batch * w);
    assert!(lut_stride >= nb * 16, "LUT stride too small for d_in");
    assert!(luts.len() >= batch * lut_stride);
    let full = nb / 8; // complete sign bytes
    const TILE_SB: usize = 16; // sign bytes per tile = 128 blocks
    out.fill(0.0);
    // (acc0, acc1) per row, interleaved; stack-resident for typical widths.
    let (mut acc_inline, mut acc_heap) = ([0.0f32; ACC_INLINE], Vec::new());
    let acc = acc_storage(&mut acc_inline, &mut acc_heap, 2 * batch);
    let mut sb0 = 0usize;
    while sb0 < full {
        let sb1 = (sb0 + TILE_SB).min(full);
        for (jj, j) in (j0..j1).enumerate() {
            let idx_plane = p.idx_plane(j);
            let sign_plane = p.sign_plane(j);
            acc.fill(0.0);
            for sb in sb0..sb1 {
                let signs = sign_plane[sb] as u32;
                let ibase = sb * 4;
                let lbase = sb * 8 * 16;
                for k in 0..4 {
                    let byte = idx_plane[ibase + k];
                    let lo = (byte & 0x0F) as usize;
                    let hi = (byte >> 4) as usize;
                    let b0 = 2 * k;
                    let o0 = lbase + b0 * 16 + lo;
                    let o1 = lbase + (b0 + 1) * 16 + hi;
                    // branchless mirror: shift the sign bit to f32 bit 31
                    let s0 = ((signs >> b0) & 1) << 31;
                    let s1 = ((signs >> (b0 + 1)) & 1) << 31;
                    for bi in 0..batch {
                        let row = &luts[bi * lut_stride..];
                        acc[2 * bi] += f32::from_bits(row[o0].to_bits() ^ s0);
                        acc[2 * bi + 1] += f32::from_bits(row[o1].to_bits() ^ s1);
                    }
                }
            }
            for bi in 0..batch {
                out[bi * w + jj] += acc[2 * bi] + acc[2 * bi + 1];
            }
        }
        sb0 = sb1;
    }
    // Tail blocks + final per-channel scale.
    for (jj, j) in (j0..j1).enumerate() {
        for bi in 0..batch {
            let mut a = out[bi * w + jj];
            let row = &luts[bi * lut_stride..];
            for b in full * 8..nb {
                let v = row[b * 16 + p.idx_at(j, b) as usize];
                let s = (p.sign_at(j, b) as u32) << 31;
                a += f32::from_bits(v.to_bits() ^ s);
            }
            out[bi * w + jj] = a * p.alpha[j];
        }
    }
}

// ---------------------------------------------------------------------------
// Sherry 1.25-bit KV attention: per-query q·k LUT walk
// ---------------------------------------------------------------------------

/// Build the per-(head, block) 32-entry q·k tables for the ternary-KV
/// attention score pass.
///
/// `q_codes` is one int8-quantized query row (`n_heads × head_dim`, the
/// output of the attention path's query quantizer). For head `h`, block
/// `b` and a stored pack34 code `(idx, mirror)`, entry
/// `luts[(h·nb + b)·32 + mirror·16 + idx]` holds
///
/// ```text
/// Σ_lane decode_block(idx, mirror)[lane] · q̂[h·head_dim + 4b + lane]
/// ```
///
/// as f32 — that block's exact integer contribution to q̂·k̂. The mirror
/// half of each table is written as the exact negation of the base half.
/// Every entry is an integer of magnitude ≤ 3·127, so f32 accumulation
/// over blocks stays exact (≤ 381·nb ≪ 2²⁴): summation order cannot
/// perturb a q·k sum, which makes the scalar and SIMD walks bit-identical
/// by construction rather than by careful operation ordering.
///
/// `luts` must have length `n_heads * (head_dim/4) * 32`.
pub fn build_qk_luts34(q_codes: &[i8], head_dim: usize, n_heads: usize, luts: &mut [f32]) {
    let nb = head_dim / 4;
    debug_assert_eq!(head_dim % 4, 0);
    debug_assert_eq!(q_codes.len(), n_heads * head_dim);
    debug_assert_eq!(luts.len(), n_heads * nb * 32);
    for h in 0..n_heads {
        for b in 0..nb {
            let q = &q_codes[h * head_dim + b * 4..h * head_dim + b * 4 + 4];
            let out = &mut luts[(h * nb + b) * 32..(h * nb + b) * 32 + 32];
            for idx in 0..16u8 {
                let pat = crate::pack::pack34::decode_block(idx, false);
                let mut s = 0i32;
                for (lane, &p) in pat.iter().enumerate() {
                    s += p as i32 * q[lane] as i32;
                }
                out[idx as usize] = s as f32;
                out[16 + idx as usize] = -(s as f32);
            }
        }
    }
}

/// Scalar q·k LUT walk over one head of a packed 3:4-ternary K plane —
/// the ground truth the `simd` walks must match bit-for-bit.
///
/// `idx` / `sign` are the packed planes laid out as in
/// [`TernaryBlock`](crate::cache::TernaryBlock): row-major over `rows`
/// token slots, each slot holding `n_heads` head lanes of `idx_bh` /
/// `sign_bh` bytes. Block `b` of a lane sits at nibble `b%2` of idx byte
/// `b/2` and bit `b%8` of sign byte `b/8`. `out[r]` receives the integer
/// dot q̂_head · k̂_head(row r) as f32 (exact — see [`build_qk_luts34`]);
/// the caller folds `q_scale · k_page_head_scale · softmax_scale` in
/// afterwards, so the walk itself is multiplication-free and never
/// materializes a dequantized K value.
#[allow(clippy::too_many_arguments)]
pub fn qk_lut34_rows(
    idx: &[u8],
    sign: &[u8],
    idx_bh: usize,
    sign_bh: usize,
    nb: usize,
    head: usize,
    n_heads: usize,
    luts: &[f32],
    rows: usize,
    out: &mut [f32],
) {
    let lh = &luts[head * nb * 32..(head + 1) * nb * 32];
    for (r, o) in out.iter_mut().enumerate().take(rows) {
        let ib = (r * n_heads + head) * idx_bh;
        let mb = (r * n_heads + head) * sign_bh;
        let mut acc = 0.0f32;
        for b in 0..nb {
            let nib = ((idx[ib + b / 2] >> ((b % 2) * 4)) & 0x0F) as usize;
            let m = ((sign[mb + b / 8] >> (b % 8)) & 1) as usize;
            acc += lh[b * 32 + m * 16 + nib];
        }
        *o = acc;
    }
}

// ---------------------------------------------------------------------------
// TL2 1.67-bit kernel
// ---------------------------------------------------------------------------

/// 32-entry stride per group (27 valid codes, padded for alignment).
pub const TL2_LUT_STRIDE: usize = 32;

/// Build the per-group 27-entry tables (stride 32) for the TL2 kernel.
/// `x` is zero-padded conceptually to a multiple of 3. Entries 27..32 of
/// each group are alignment padding: valid 5-bit codes are always < 27,
/// so the kernel never reads them — they are still zeroed here because
/// scratch reuse relies on builders fully owning the region they claim
/// (see `Scratch::lut_buf`): a builder that skipped lanes would expose
/// a previous layer's stale entries.
pub fn build_luts_tl2(x: &[f32], luts: &mut [f32]) {
    let ng = x.len().div_ceil(3);
    debug_assert_eq!(luts.len(), ng * TL2_LUT_STRIDE);
    let get = |i: usize| if i < x.len() { x[i] } else { 0.0 };
    for g in 0..ng {
        let (x0, x1, x2) = (get(g * 3), get(g * 3 + 1), get(g * 3 + 2));
        let out = &mut luts[g * TL2_LUT_STRIDE..g * TL2_LUT_STRIDE + TL2_LUT_STRIDE];
        let mut code = 0usize;
        for t0 in [-1.0f32, 0.0, 1.0] {
            let p0 = t0 * x0; // one fused level; 3-way pattern can't use the
            for t1 in [-1.0f32, 0.0, 1.0] {
                let p01 = p0 + t1 * x1; // ± trick as cleanly as 4-way
                out[code] = p01 - x2;
                out[code + 1] = p01;
                out[code + 2] = p01 + x2;
                code += 3;
            }
        }
        out[27..].fill(0.0);
    }
}

/// y = (PackedTl2 weights) · x with per-channel α.
pub fn gemv_tl2(p: &PackedTl2, x: &[f32], luts: &mut [f32], y: &mut [f32]) {
    assert_eq!(x.len(), p.d_in);
    assert_eq!(y.len(), p.d_out);
    build_luts_tl2(x, luts);
    gemv_tl2_preluts(p, luts, y);
}

/// Single-row TL2 accumulate phase; `batch = 1` case of
/// [`gemm_tl2_preluts`].
pub fn gemv_tl2_preluts(p: &PackedTl2, luts: &[f32], y: &mut [f32]) {
    gemm_tl2_preluts(p, luts, luts.len(), 1, 0, p.d_out, y);
}

/// Batched TL2 accumulate over channels `[j0, j1)`: every code extraction
/// is a misaligned 16-bit load + shift + mask — the bit-shuffling overhead
/// of 3-way packing. Batching pays that decode cost once per code and
/// indexes all `batch` tables with it. `out` layout as in
/// [`gemm_pack34_preluts`].
pub fn gemm_tl2_preluts(
    p: &PackedTl2,
    luts: &[f32],
    lut_stride: usize,
    batch: usize,
    j0: usize,
    j1: usize,
    out: &mut [f32],
) {
    let ng = p.n_groups();
    assert!(j0 <= j1 && j1 <= p.d_out);
    let w = j1 - j0;
    assert_eq!(out.len(), batch * w);
    assert!(lut_stride >= ng * TL2_LUT_STRIDE, "LUT stride too small for d_in");
    assert!(luts.len() >= batch * lut_stride);
    let (mut acc_inline, mut acc_heap) = ([0.0f32; ACC_INLINE], Vec::new());
    let acc = acc_storage(&mut acc_inline, &mut acc_heap, batch);
    for (jj, j) in (j0..j1).enumerate() {
        let stream = p.stream(j);
        acc.fill(0.0);
        let mut bit_off = 0usize;
        for g in 0..ng {
            let byte = bit_off / 8;
            let shift = bit_off % 8;
            let lo = stream[byte] as u16;
            let hi = if byte + 1 < stream.len() { stream[byte + 1] as u16 } else { 0 };
            let code = (((hi << 8) | lo) >> shift) as usize & 0x1F;
            let o = g * TL2_LUT_STRIDE + code;
            for (bi, a) in acc.iter_mut().enumerate() {
                *a += luts[bi * lut_stride + o];
            }
            bit_off += 5;
        }
        for (bi, &a) in acc.iter().enumerate() {
            out[bi * w + jj] = a * p.alpha[j];
        }
    }
}

// ---------------------------------------------------------------------------
// I2_S 2-bit kernel
// ---------------------------------------------------------------------------

/// Per-byte decode table: byte → the 4 ternary multipliers it encodes.
/// 256×4 f32 = 4 KiB, L1-resident. This is the scalar analogue of the
/// SIMD sign/zero-mask unpack BitNet.cpp's I2_S kernel performs.
static I2S_DECODE: [[f32; 4]; 256] = build_i2s_decode();

const fn build_i2s_decode() -> [[f32; 4]; 256] {
    let mut t = [[0.0f32; 4]; 256];
    let mut b = 0usize;
    while b < 256 {
        let mut k = 0usize;
        while k < 4 {
            let code = (b >> (k * 2)) & 0x3;
            t[b][k] = match code {
                0 => -1.0,
                2 => 1.0,
                _ => 0.0,
            };
            k += 1;
        }
        b += 1;
    }
    t
}

/// Borrow the decode-table row for one packed byte (the `simd` walks
/// share the scalar kernel's table so their multiplier values are
/// identical by construction).
#[inline(always)]
pub(crate) fn i2s_multipliers(byte: u8) -> &'static [f32; 4] {
    &I2S_DECODE[byte as usize]
}

/// y = (PackedI2S weights) · x with per-channel α; `batch = 1` case of
/// [`gemm_i2s`].
pub fn gemv_i2s(p: &PackedI2S, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), p.d_in);
    assert_eq!(y.len(), p.d_out);
    gemm_i2s(p, x, 1, 0, p.d_out, y);
}

/// Batched I2_S decode-and-add over channels `[j0, j1)`. `xs` holds
/// `batch` activation rows back to back (`batch × d_in`); there is no LUT
/// phase for this format, so batching amortizes only the weight-byte
/// decode. `out` layout as in [`gemm_pack34_preluts`].
///
/// Perf notes (§Perf): the first version selected ±x with a data-dependent
/// `match` — ~50% mispredict per weight, 0.15 Gw/s. Now each packed byte
/// indexes a 4-KiB decode table of ternary multipliers and the inner loop
/// is 4 FMAs per byte per row, which LLVM vectorizes (this mirrors the
/// real BitNet.cpp I2_S kernel, which unpacks to SIMD multiplier lanes).
pub fn gemm_i2s(p: &PackedI2S, xs: &[f32], batch: usize, j0: usize, j1: usize, out: &mut [f32]) {
    let d_in = p.d_in;
    assert!(j0 <= j1 && j1 <= p.d_out);
    let w = j1 - j0;
    assert_eq!(xs.len(), batch * d_in);
    assert_eq!(out.len(), batch * w);
    let full_bytes = d_in / 4;
    let pairs = full_bytes / 2;
    // (acc0, acc1) per row, interleaved; stack-resident for typical widths.
    let (mut acc_inline, mut acc_heap) = ([0.0f32; ACC_INLINE], Vec::new());
    let acc = acc_storage(&mut acc_inline, &mut acc_heap, 2 * batch);
    for (jj, j) in (j0..j1).enumerate() {
        let ch = p.channel(j);
        acc.fill(0.0);
        for bp in 0..pairs {
            let m0 = &I2S_DECODE[ch[2 * bp] as usize];
            let m1 = &I2S_DECODE[ch[2 * bp + 1] as usize];
            for bi in 0..batch {
                let xb = &xs[bi * d_in + bp * 8..bi * d_in + bp * 8 + 8];
                acc[2 * bi] += m0[0] * xb[0] + m0[1] * xb[1] + m0[2] * xb[2] + m0[3] * xb[3];
                acc[2 * bi + 1] += m1[0] * xb[4] + m1[1] * xb[5] + m1[2] * xb[6] + m1[3] * xb[7];
            }
        }
        for i in pairs * 8..d_in {
            let m = I2S_DECODE[ch[i / 4] as usize][i % 4];
            for bi in 0..batch {
                acc[2 * bi] += m * xs[bi * d_in + i];
            }
        }
        for bi in 0..batch {
            out[bi * w + jj] = (acc[2 * bi] + acc[2 * bi + 1]) * p.alpha[j];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{absmean_quantize, sherry34_quantize, Granularity};
    use crate::tensor::{ops::gemv_f32, Mat};
    use crate::util::{prop, Pcg64};

    /// Dense reference: y = (Tα)ᵀ · x computed at f32.
    fn dense_ref(q: &crate::quant::Ternary, x: &[f32]) -> Vec<f32> {
        let deq = q.dequant(); // (d_in, d_out)
        let wt = deq.transpose(); // (d_out, d_in)
        let mut y = vec![0.0; q.d_out];
        gemv_f32(&wt.data, q.d_out, q.d_in, x, &mut y);
        y
    }

    fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() <= tol * (1.0 + y.abs()), "{what}[{i}]: {x} vs {y}");
        }
    }

    #[test]
    fn pack34_matches_dense() {
        let mut rng = Pcg64::seeded(0);
        let w = Mat::randn(&mut rng, 512, 64, 1.0);
        let q = sherry34_quantize(&w, Granularity::PerChannel);
        let p = Packed34::from_ternary(&q);
        let x = rng.normal_vec(512);
        let mut luts = vec![0.0; (512 / 4) * 16];
        let mut y = vec![0.0; 64];
        gemv_pack34(&p, &x, &mut luts, &mut y);
        assert_close(&y, &dense_ref(&q, &x), 1e-4, "pack34");
    }

    #[test]
    fn prop_pack34_matches_dense_all_shapes() {
        prop::check(
            "lut34 == dense",
            25,
            |rng| {
                let nb = prop::gens::usize_in(rng, 1, 64);
                let d_out = prop::gens::usize_in(rng, 1, 32);
                (nb * 4, d_out, rng.next_u64())
            },
            |&(d_in, d_out, seed)| {
                let mut rng = Pcg64::seeded(seed);
                let w = Mat::randn(&mut rng, d_in, d_out, 1.0);
                let q = sherry34_quantize(&w, Granularity::PerChannel);
                let p = Packed34::from_ternary(&q);
                let x = rng.normal_vec(d_in);
                let mut luts = vec![0.0; (d_in / 4) * 16];
                let mut y = vec![0.0; d_out];
                gemv_pack34(&p, &x, &mut luts, &mut y);
                let expect = dense_ref(&q, &x);
                for (a, b) in y.iter().zip(&expect) {
                    if (a - b).abs() > 1e-3 * (1.0 + b.abs()) {
                        return Err(format!("{a} vs {b}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn tl2_matches_dense() {
        let mut rng = Pcg64::seeded(1);
        for d_in in [510usize, 512, 513] {
            let w = Mat::randn(&mut rng, d_in, 32, 1.0);
            let q = absmean_quantize(&w, Granularity::PerChannel);
            let p = PackedTl2::from_ternary(&q);
            let x = rng.normal_vec(d_in);
            let mut luts = vec![0.0; d_in.div_ceil(3) * TL2_LUT_STRIDE];
            let mut y = vec![0.0; 32];
            gemv_tl2(&p, &x, &mut luts, &mut y);
            assert_close(&y, &dense_ref(&q, &x), 1e-4, "tl2");
        }
    }

    #[test]
    fn i2s_matches_dense() {
        let mut rng = Pcg64::seeded(2);
        for d_in in [511usize, 512] {
            let w = Mat::randn(&mut rng, d_in, 32, 1.0);
            let q = absmean_quantize(&w, Granularity::PerChannel);
            let p = PackedI2S::from_ternary(&q);
            let x = rng.normal_vec(d_in);
            let mut y = vec![0.0; 32];
            gemv_i2s(&p, &x, &mut y);
            assert_close(&y, &dense_ref(&q, &x), 1e-4, "i2s");
        }
    }

    #[test]
    fn batched_range_kernels_match_full_range() {
        // Splitting the channel range must not change any output value:
        // channels are independent, so a [0,d_out) call and two half-range
        // calls must agree exactly.
        let mut rng = Pcg64::seeded(7);
        let (d_in, d_out, b) = (96usize, 40usize, 3usize);
        let w = Mat::randn(&mut rng, d_in, d_out, 1.0);
        let q = sherry34_quantize(&w, Granularity::PerChannel);
        let p = Packed34::from_ternary(&q);
        let stride = (d_in / 4) * 16;
        let xs: Vec<f32> = rng.normal_vec(b * d_in);
        let mut luts = vec![0.0; b * stride];
        for bi in 0..b {
            build_luts34(&xs[bi * d_in..(bi + 1) * d_in], &mut luts[bi * stride..(bi + 1) * stride]);
        }
        let mut full = vec![0.0; b * d_out];
        gemm_pack34_preluts(&p, &luts, stride, b, 0, d_out, &mut full);
        let mid = d_out / 2;
        let mut lo = vec![0.0; b * mid];
        let mut hi = vec![0.0; b * (d_out - mid)];
        gemm_pack34_preluts(&p, &luts, stride, b, 0, mid, &mut lo);
        gemm_pack34_preluts(&p, &luts, stride, b, mid, d_out, &mut hi);
        for bi in 0..b {
            for j in 0..d_out {
                let split = if j < mid { lo[bi * mid + j] } else { hi[bi * (d_out - mid) + (j - mid)] };
                assert_eq!(full[bi * d_out + j], split, "row {bi} ch {j}");
            }
        }
    }

    #[test]
    fn pack34_matches_python_golden() {
        let dir = crate::test_artifacts_dir().join("golden");
        if !dir.join("w.bin").exists() {
            eprintln!("skipping: goldens not built");
            return;
        }
        let (r, c, wd) = crate::util::binio::read_mat(&dir.join("w.bin")).unwrap();
        let w = Mat::from_vec(r, c, wd);
        let q = sherry34_quantize(&w, Granularity::PerChannel);
        let p = Packed34::from_ternary(&q);
        let (_, _, xd) = crate::util::binio::read_mat(&dir.join("x.bin")).unwrap();
        let (yr, yc, y_gold) = crate::util::binio::read_mat(&dir.join("sherry34.y.bin")).unwrap();
        assert_eq!((yr, yc), (16, c));
        let mut luts = vec![0.0; (r / 4) * 16];
        let mut y = vec![0.0; c];
        for t in 0..16 {
            gemv_pack34(&p, &xd[t * r..(t + 1) * r], &mut luts, &mut y);
            for j in 0..c {
                let g = y_gold[t * c + j];
                assert!((y[j] - g).abs() < 1e-3 * (1.0 + g.abs()), "row {t} col {j}: {} vs {g}", y[j]);
            }
        }
    }

    #[test]
    fn qk_luts34_mirror_half_is_exact_negation() {
        let (nh, hd) = (2usize, 8usize);
        let nb = hd / 4;
        let q: Vec<i8> = (0..nh * hd).map(|i| ((i * 31 + 7) % 255) as i8).collect();
        let mut luts = vec![0.0f32; nh * nb * 32];
        build_qk_luts34(&q, hd, nh, &mut luts);
        for t in 0..nh * nb {
            for idx in 0..16 {
                let a = luts[t * 32 + idx];
                let b = luts[t * 32 + 16 + idx];
                assert_eq!(a.to_bits(), (-b).to_bits(), "table {t} idx {idx}");
                assert_eq!(a, a.round(), "entries are integer-valued");
                assert!(a.abs() <= 3.0 * 127.0);
            }
        }
    }

    #[test]
    fn qk_lut34_rows_matches_decoded_dot() {
        // Pack a synthetic K plane by hand (nibble idx + mirror bit-plane,
        // the TernaryBlock layout), then check the LUT walk against the
        // decode-then-integer-dot reference for every (row, head).
        use crate::pack::pack34::decode_block;
        let (rows, nh, hd) = (5usize, 3usize, 12usize);
        let nb = hd / 4;
        let (idx_bh, sign_bh) = (nb.div_ceil(2), nb.div_ceil(8));
        let mut idx = vec![0u8; rows * nh * idx_bh];
        let mut sign = vec![0u8; rows * nh * sign_bh];
        let code = |r: usize, h: usize, b: usize| ((r * 7 + h * 3 + b * 5) % 16) as u8;
        let mirror = |r: usize, h: usize, b: usize| (r + h + b) % 2 == 0;
        for r in 0..rows {
            for h in 0..nh {
                let lane = r * nh + h;
                for b in 0..nb {
                    idx[lane * idx_bh + b / 2] |= code(r, h, b) << ((b % 2) * 4);
                    sign[lane * sign_bh + b / 8] |= (mirror(r, h, b) as u8) << (b % 8);
                }
            }
        }
        let q: Vec<i8> = (0..nh * hd).map(|i| ((i * 67 + 19) % 255 - 127) as i8).collect();
        let mut luts = vec![0.0f32; nh * nb * 32];
        build_qk_luts34(&q, hd, nh, &mut luts);
        let mut out = vec![0.0f32; rows];
        for h in 0..nh {
            qk_lut34_rows(&idx, &sign, idx_bh, sign_bh, nb, h, nh, &luts, rows, &mut out);
            for (r, &got) in out.iter().enumerate() {
                let mut want = 0i32;
                for b in 0..nb {
                    let k = decode_block(code(r, h, b), mirror(r, h, b));
                    for lane in 0..4 {
                        want += k[lane] as i32 * q[h * hd + b * 4 + lane] as i32;
                    }
                }
                assert_eq!(got, want as f32, "row {r} head {h}");
            }
        }
    }

    #[test]
    fn luts34_entries_are_signed_sums() {
        let x = [1.0f32, 2.0, 4.0, 8.0];
        let mut luts = vec![0.0; 16];
        build_luts34(&x, &mut luts);
        // z=0 (active 1,2,3): idx 0 → +2+4+8 = 14; idx 3 → +2−4−8 = −10
        assert_eq!(luts[0], 14.0);
        assert_eq!(luts[3], -10.0);
        // z=3 (active 0,1,2): idx 12 → 1+2+4 = 7
        assert_eq!(luts[12], 7.0);
    }
}
