//! Native edge-inference engine: the unified [`TernaryKernel`] dispatch
//! over the packed formats, quantized linear layers, and a full ternary
//! transformer with KV cache for token generation (the Table 4 / Fig. 1
//! measurement target).
//!
//! The engine is Python-free: it either quantizes weights on load (PTQ)
//! or consumes QAT checkpoints exported by the training driver. Serving
//! has two granularities — single-token [`TernaryModel::forward_one`] and
//! the batched [`TernaryModel::forward_batch`] the continuous batcher
//! drives, which issues one fused LUT-GEMM per layer per decode round.

pub mod kernel;
pub mod lut;
mod linear;
mod model;

pub use kernel::{DenseKernel, Scratch, TernaryKernel};
pub use linear::QuantLinear;
pub use model::{argmax, random_weights, KvCache, ModelWeights, NativeConfig, TernaryModel};
