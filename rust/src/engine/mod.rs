//! Native edge-inference engine: the unified [`TernaryKernel`] dispatch
//! over the packed formats, quantized linear layers, and a full ternary
//! transformer with KV cache for token generation (the Table 4 / Fig. 1
//! measurement target).
//!
//! The engine is Python-free: it either quantizes weights on load (PTQ)
//! or consumes QAT checkpoints exported by the training driver. Serving
//! has two granularities — single-token [`TernaryModel::forward_one`] and
//! the batched [`TernaryModel::forward_batch`] the continuous batcher
//! drives, which issues one fused LUT-GEMM per layer per decode round.
//! Attention reads KV history through the `cache` subsystem's block
//! views at the storage dtype: int8 pages contribute q·k scores as i32
//! integer dots over raw page bytes, 1.25-bit ternary K pages as
//! per-query LUT walks over their packed pack34 codes (never
//! dequantized), and f32 pages as borrowed tiles — bit-for-bit with the
//! contiguous pre-paging engine (DESIGN.md §4).
//!
//! Invariants: batched vs single-row kernels are bit-for-bit per format
//! (`gemv` *is* `gemm_nt` at `B = 1`); decode never feeds a position at
//! or past `seq_len` (the coordinator finishes such sequences with
//! `ContextLimit`); and no kernel mutates weights after construction —
//! models are `Send + Sync` and shared read-only across the pool.

pub mod kernel;
pub mod lut;
mod linear;
mod model;

pub use kernel::{DenseKernel, Scratch, TernaryKernel};
pub use linear::QuantLinear;
pub use model::{argmax, random_weights, KvCache, ModelWeights, NativeConfig, TernaryModel};
