//! Native edge-inference engine: quantized linear layers over the packed
//! formats, and a full ternary transformer with KV cache for token
//! generation (the Table 4 / Fig. 1 measurement target).
//!
//! The engine is Python-free: it either quantizes weights on load (PTQ)
//! or consumes QAT checkpoints exported by the training driver.

pub mod lut;
mod linear;
mod model;

pub use linear::{QuantLinear, Scratch};
pub use model::{argmax, random_weights, KvCache, ModelWeights, NativeConfig, TernaryModel};
