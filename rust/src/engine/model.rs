//! Native ternary transformer inference with KV cache — the end-to-end
//! token-generation path measured in Table 4, mirroring the Layer-2
//! architecture (`python/compile/model.py`) exactly so QAT checkpoints
//! serve natively.
//!
//! Embedding and LM head stay float (the paper quantizes "all linear
//! layers within the Transformer architecture"; BitNet-style models keep
//! embed/head in high precision).

use std::collections::BTreeMap;

use super::kernel::Scratch;
use super::linear::QuantLinear;
use crate::cache::{KvBatch, Rows};
use crate::pack::Format;
use crate::tensor::{ops, Mat};
use crate::util::{BufferPool, Pcg64, ThreadPool};

/// Architecture hyper-parameters (keep in sync with
/// `python/compile/model.py::CONFIGS`).
#[derive(Clone, Copy, Debug)]
pub struct NativeConfig {
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
}

impl NativeConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Named presets matching the Python side.
    pub fn named(name: &str) -> Option<Self> {
        match name {
            "nano" => Some(Self { vocab_size: 256, d_model: 128, n_layers: 2, n_heads: 4, d_ff: 384, seq_len: 64 }),
            "micro" => Some(Self { vocab_size: 512, d_model: 256, n_layers: 4, n_heads: 4, d_ff: 768, seq_len: 128 }),
            "e2e" => Some(Self { vocab_size: 1024, d_model: 384, n_layers: 6, n_heads: 6, d_ff: 1152, seq_len: 128 }),
            // Paper-scale layer shapes for Table 4 benchmarking (vocab
            // truncated: the bench measures the transformer stack).
            "bench700m" => Some(Self { vocab_size: 4096, d_model: 1536, n_layers: 24, n_heads: 16, d_ff: 4096, seq_len: 256 }),
            "bench3b" => Some(Self { vocab_size: 4096, d_model: 3200, n_layers: 26, n_heads: 32, d_ff: 8640, seq_len: 256 }),
            _ => None,
        }
    }
}

/// Float parameter set (as trained / initialized), keyed by the Layer-2
/// names in `{cfg}.params.tsv`.
pub type ModelWeights = BTreeMap<String, Mat>;

/// Random-initialized weights (benches and smoke tests).
pub fn random_weights(cfg: &NativeConfig, seed: u64) -> ModelWeights {
    let mut rng = Pcg64::seeded(seed);
    let mut w = ModelWeights::new();
    let d = cfg.d_model;
    w.insert("embed".into(), Mat::randn(&mut rng, cfg.vocab_size, d, (d as f32).powf(-0.5)));
    for i in 0..cfg.n_layers {
        let p = format!("layer{i}.");
        w.insert(format!("{p}norm_attn"), Mat::from_vec(1, d, vec![1.0; d]));
        w.insert(format!("{p}norm_mlp"), Mat::from_vec(1, d, vec![1.0; d]));
        for (name, rows, cols) in [
            ("wq", d, d),
            ("wk", d, d),
            ("wv", d, d),
            ("wo", d, d),
            ("w_gate", d, cfg.d_ff),
            ("w_up", d, cfg.d_ff),
            ("w_down", cfg.d_ff, d),
        ] {
            w.insert(format!("{p}{name}"), Mat::randn(&mut rng, rows, cols, (rows as f32).powf(-0.5)));
        }
    }
    w.insert("norm_out".into(), Mat::from_vec(1, d, vec![1.0; d]));
    w.insert("lm_head".into(), Mat::randn(&mut rng, d, cfg.vocab_size, (d as f32).powf(-0.5)));
    w
}

struct Layer {
    norm_attn: Vec<f32>,
    norm_mlp: Vec<f32>,
    wq: QuantLinear,
    wk: QuantLinear,
    wv: QuantLinear,
    wo: QuantLinear,
    w_gate: QuantLinear,
    w_up: QuantLinear,
    w_down: QuantLinear,
}

/// Per-sequence contiguous KV cache — the degenerate single-table case
/// of the paged subsystem (`crate::cache`): single-stream paths (eval,
/// [`TernaryModel::generate`]) keep this dense layout, while the serving
/// coordinator decodes through paged [`BlockTable`]s. Both feed the same
/// [`KvBatch`] view, so the numeric path is identical.
///
/// [`BlockTable`]: crate::cache::BlockTable
pub struct KvCache {
    /// `[layer][pos * d_model + c]`
    pub(crate) k: Vec<Vec<f32>>,
    pub(crate) v: Vec<Vec<f32>>,
    pub len: usize,
    /// Model width (for external byte accounting).
    pub d_model: usize,
}

impl KvCache {
    pub fn new(cfg: &NativeConfig) -> Self {
        let cap = cfg.seq_len * cfg.d_model;
        Self {
            k: (0..cfg.n_layers).map(|_| Vec::with_capacity(cap)).collect(),
            v: (0..cfg.n_layers).map(|_| Vec::with_capacity(cap)).collect(),
            len: 0,
            d_model: cfg.d_model,
        }
    }

    pub fn clear(&mut self) {
        for k in &mut self.k {
            k.clear();
        }
        for v in &mut self.v {
            v.clear();
        }
        self.len = 0;
    }

    /// Approximate resident bytes (metrics / KV pool accounting).
    pub fn bytes(&self) -> usize {
        self.k.iter().chain(self.v.iter()).map(|b| b.len() * 4).sum()
    }
}

/// The native quantized transformer.
pub struct TernaryModel {
    pub cfg: NativeConfig,
    pub format: Format,
    embed: Mat,
    layers: Vec<Layer>,
    norm_out: Vec<f32>,
    lm_head: QuantLinear,
    /// Leased scratch tiles for the page-blocked attention walk (score
    /// rows + dequantized KV blocks), reused across decode rounds.
    tiles: BufferPool,
}

impl TernaryModel {
    /// Build from float weights, quantizing every transformer linear into
    /// `format` (embed + lm_head stay float/dense).
    pub fn build(cfg: NativeConfig, weights: &ModelWeights, format: Format) -> Self {
        let get = |name: &str| weights.get(name).unwrap_or_else(|| panic!("missing weight {name}"));
        let layers = (0..cfg.n_layers)
            .map(|i| {
                let p = format!("layer{i}.");
                Layer {
                    norm_attn: get(&format!("{p}norm_attn")).data.clone(),
                    norm_mlp: get(&format!("{p}norm_mlp")).data.clone(),
                    wq: QuantLinear::from_float(get(&format!("{p}wq")), format),
                    wk: QuantLinear::from_float(get(&format!("{p}wk")), format),
                    wv: QuantLinear::from_float(get(&format!("{p}wv")), format),
                    wo: QuantLinear::from_float(get(&format!("{p}wo")), format),
                    w_gate: QuantLinear::from_float(get(&format!("{p}w_gate")), format),
                    w_up: QuantLinear::from_float(get(&format!("{p}w_up")), format),
                    w_down: QuantLinear::from_float(get(&format!("{p}w_down")), format),
                }
            })
            .collect();
        Self {
            cfg,
            format,
            embed: get("embed").clone(),
            layers,
            norm_out: get("norm_out").data.clone(),
            lm_head: QuantLinear::from_float(get("lm_head"), Format::Dense),
            tiles: BufferPool::new(),
        }
    }

    /// Build with an explicit quantization *method* (PTQ of QAT-trained
    /// latents — the deployed-model path of the eval harness). Sherry
    /// serves through the packed LUT engine; every other method serves
    /// its dequantized weights densely (their packings don't affect
    /// accuracy, only speed, which Table 4 measures separately).
    pub fn build_ptq(
        cfg: NativeConfig,
        weights: &ModelWeights,
        method: crate::quant::Method,
        granularity: crate::quant::Granularity,
    ) -> Self {
        use crate::quant::{quantize, Method};
        let mut q_weights = ModelWeights::new();
        for (name, w) in weights {
            let is_linear = name.contains("layer") && !name.contains("norm") && !name.ends_with(".aux");
            if is_linear {
                let q = quantize(w, method, granularity);
                q_weights.insert(name.clone(), q.dequant());
            } else if !name.ends_with(".aux") {
                q_weights.insert(name.clone(), w.clone());
            }
        }
        let format = if method == Method::Sherry34
            && matches!(granularity, crate::quant::Granularity::PerChannel)
        {
            // Serve Sherry through the real 1.25-bit LUT path.
            let mut m = Self::build(cfg, weights, Format::Sherry);
            // norms/embed/head come from `weights` already; done.
            m.format = Format::Sherry;
            return m;
        } else {
            Format::Dense
        };
        Self::build(cfg, &q_weights, format)
    }

    /// Total model bytes (quantized linears + float embed/head/norms) —
    /// the Table 4 "Size (MB)" column.
    pub fn bytes(&self) -> usize {
        let mut b = self.embed.data.len() * 2 + self.norm_out.len() * 2; // bf16 floats
        b += self.lm_head.bytes();
        for l in &self.layers {
            b += (l.norm_attn.len() + l.norm_mlp.len()) * 2;
            for lin in [&l.wq, &l.wk, &l.wv, &l.wo, &l.w_gate, &l.w_up, &l.w_down] {
                b += lin.bytes();
            }
        }
        b
    }

    /// One decode step: feed `token` at position `cache.len`, return
    /// logits. Thin `batch = 1` wrapper over [`TernaryModel::forward_batch`]
    /// — single-stream and batched decoding are the same code path, so a
    /// sequence's logits do not depend on who it shares a round with.
    pub fn forward_one(&self, token: u32, cache: &mut KvCache, scratch: &mut Scratch) -> Vec<f32> {
        self.forward_batch(&[token], &mut [cache], scratch, None).data
    }

    /// One batched decode step across `tokens.len()` sequences, each with
    /// its own KV cache (sequences may sit at different positions — the
    /// continuous-batching case). Appends one K/V row per sequence per
    /// layer and returns the `batch × vocab` logits.
    ///
    /// Every linear goes through one fused [`kernel
    /// gemm_nt`](crate::engine::TernaryKernel::gemm_nt): activation LUTs
    /// for the whole batch are built once per layer input, then each
    /// packed weight plane is walked a single time with all LUTs resident,
    /// fanned out over output-channel tiles on `pool`. Attention, norms
    /// and the SwiGLU are applied per sequence row (identical scalar code
    /// to the single-stream path).
    pub fn forward_batch(
        &self,
        tokens: &[u32],
        caches: &mut [&mut KvCache],
        scratch: &mut Scratch,
        pool: Option<&ThreadPool>,
    ) -> Mat {
        let mut kv = KvBatch::Contig(caches);
        self.forward_kv(tokens, &mut kv, scratch, pool)
    }

    /// One batched decode step through a [`KvBatch`] storage view —
    /// contiguous caches and the paged block-table arena run this same
    /// code, so paged serving is bit-for-bit identical to the contiguous
    /// baseline (DESIGN.md §4).
    pub fn forward_kv(
        &self,
        tokens: &[u32],
        kv: &mut KvBatch<'_, '_>,
        scratch: &mut Scratch,
        pool: Option<&ThreadPool>,
    ) -> Mat {
        let b = tokens.len();
        assert_eq!(kv.batch(), b, "one KV backing per sequence");
        let cfg = &self.cfg;
        let d = cfg.d_model;
        let hd = cfg.head_dim();
        // Per-sequence decode positions (continuous batching: they differ).
        let pos: Vec<usize> = (0..b).map(|i| kv.pos(i)).collect();
        for &p in &pos {
            // Contract with the coordinator: a sequence at the context
            // limit must be finished with FinishReason::ContextLimit, not
            // fed — see coordinator/server.rs planning.
            assert!(p < cfg.seq_len, "decode position {p} past context limit {}", cfg.seq_len);
        }
        // Paged backing: allocate / copy-on-write each sequence's next
        // slot once, before any layer writes or reads.
        kv.begin_step();

        let mut h = vec![0.0f32; b * d];
        for (bi, &tok) in tokens.iter().enumerate() {
            h[bi * d..(bi + 1) * d].copy_from_slice(self.embed.row(tok as usize));
        }

        let mut xn = vec![0.0f32; b * d];
        let mut q = vec![0.0f32; b * d];
        let mut k = vec![0.0f32; b * d];
        let mut v = vec![0.0f32; b * d];
        let mut att_out = vec![0.0f32; b * d];
        let mut proj = vec![0.0f32; b * d];
        let mut gate = vec![0.0f32; b * cfg.d_ff];
        let mut up = vec![0.0f32; b * cfg.d_ff];
        let scale = (hd as f32).powf(-0.5);

        for (li, layer) in self.layers.iter().enumerate() {
            // --- attention block ---
            xn.copy_from_slice(&h);
            for bi in 0..b {
                ops::rmsnorm_inplace(&mut xn[bi * d..(bi + 1) * d], &layer.norm_attn);
            }
            layer.wq.forward_batch(&xn, &mut q, b, scratch, pool);
            layer.wk.forward_batch(&xn, &mut k, b, scratch, pool);
            layer.wv.forward_batch(&xn, &mut v, b, scratch, pool);
            for bi in 0..b {
                // RoPE per head (matches L2: per-head half-pairing).
                for hh in 0..cfg.n_heads {
                    ops::rope_inplace(&mut q[bi * d + hh * hd..bi * d + (hh + 1) * hd], pos[bi]);
                    ops::rope_inplace(&mut k[bi * d + hh * hd..bi * d + (hh + 1) * hd], pos[bi]);
                }
                kv.append(li, bi, &k[bi * d..(bi + 1) * d], &v[bi * d..(bi + 1) * d]);
            }
            // Per-sequence attention over each sequence's own KV history —
            // independent across sequences, so it fans out on the pool
            // alongside the fused linears. The walk is page-blocked: each
            // resident page is materialized once (borrowed for f32,
            // dequantized into a leased scratch tile for quantized
            // stores), then every query·key dot product and value
            // accumulation over that page runs from the tile — the same
            // amortization gemm_nt applies to weight planes. Per-element
            // float ops and their order are identical to the old
            // position-at-a-time walk, preserving bit-for-bit parity for
            // f32 storage.
            {
                let kv_ro: &KvBatch = kv;
                let n_heads = cfg.n_heads;
                let tiles = &self.tiles;
                match pool {
                    Some(pool) if b > 1 => pool.scope(|s| {
                        for (bi, out_row) in att_out.chunks_mut(d).enumerate() {
                            let kl = kv_ro.k_rows(li, bi);
                            let vl = kv_ro.v_rows(li, bi);
                            let q_row = &q[bi * d..(bi + 1) * d];
                            let t = pos[bi] + 1;
                            s.spawn(move || {
                                let mut scores = tiles.lease();
                                let mut tile = tiles.lease();
                                attention_blocked(
                                    q_row, kl, vl, t, hd, n_heads, scale, &mut scores,
                                    &mut tile, out_row,
                                );
                                tiles.give(tile);
                                tiles.give(scores);
                            });
                        }
                    }),
                    _ => {
                        let mut scores = tiles.lease();
                        let mut tile = tiles.lease();
                        for (bi, out_row) in att_out.chunks_mut(d).enumerate() {
                            let kl = kv_ro.k_rows(li, bi);
                            let vl = kv_ro.v_rows(li, bi);
                            let q_row = &q[bi * d..(bi + 1) * d];
                            attention_blocked(
                                q_row, kl, vl, pos[bi] + 1, hd, n_heads, scale, &mut scores,
                                &mut tile, out_row,
                            );
                        }
                        tiles.give(tile);
                        tiles.give(scores);
                    }
                }
            }
            layer.wo.forward_batch(&att_out, &mut proj, b, scratch, pool);
            for (hi, &p) in h.iter_mut().zip(proj.iter()) {
                *hi += p;
            }

            // --- MLP block (SwiGLU) ---
            xn.copy_from_slice(&h);
            for bi in 0..b {
                ops::rmsnorm_inplace(&mut xn[bi * d..(bi + 1) * d], &layer.norm_mlp);
            }
            layer.w_gate.forward_batch(&xn, &mut gate, b, scratch, pool);
            layer.w_up.forward_batch(&xn, &mut up, b, scratch, pool);
            for (g, &u) in gate.iter_mut().zip(up.iter()) {
                let s = *g;
                *g = s / (1.0 + (-s).exp()) * u; // silu(g) * u
            }
            layer.w_down.forward_batch(&gate, &mut proj, b, scratch, pool);
            for (hi, &p) in h.iter_mut().zip(proj.iter()) {
                *hi += p;
            }
        }
        kv.advance();

        for bi in 0..b {
            ops::rmsnorm_inplace(&mut h[bi * d..(bi + 1) * d], &self.norm_out);
        }
        let mut logits = vec![0.0f32; b * cfg.vocab_size];
        self.lm_head.forward_batch(&h, &mut logits, b, scratch, pool);
        Mat::from_vec(b, cfg.vocab_size, logits)
    }

    /// Greedy-generate `n_tokens` starting from `prompt`. Returns the
    /// generated ids (prompt excluded).
    pub fn generate(&self, prompt: &[u32], n_tokens: usize, cache: &mut KvCache, scratch: &mut Scratch) -> Vec<u32> {
        cache.clear();
        let mut logits = Vec::new();
        for &tok in prompt {
            logits = self.forward_one(tok, cache, scratch);
        }
        let mut out = Vec::with_capacity(n_tokens);
        let mut next = argmax(&logits) as u32;
        for _ in 0..n_tokens {
            out.push(next);
            if cache.len >= self.cfg.seq_len {
                break;
            }
            logits = self.forward_one(next, cache, scratch);
            next = argmax(&logits) as u32;
        }
        out
    }
}

/// Page-blocked causal attention for one sequence at its current decode
/// position, writing the `d_model`-wide output row. One shared body for
/// the serial and pool-fanned paths of [`TernaryModel::forward_kv`].
///
/// Three passes over `t` cached timesteps, each walking the history as
/// page blocks ([`Rows::for_each_block`]): (1) every head's query·key
/// dot products into `scores` (`n_heads × t`), (2) per-head softmax,
/// (3) weighted-V accumulation. A page is materialized at most once per
/// pass — borrowed for f32 storage, dequantized once into `tile` for
/// quantized storage — instead of being re-resolved per position. Blocks
/// arrive in ascending position order and every per-element float op
/// matches the old position-at-a-time walk, so f32 storage (paged or
/// contiguous) is bit-for-bit identical to the pre-blocked kernel.
#[allow(clippy::too_many_arguments)]
fn attention_blocked(
    q_row: &[f32],
    kl: Rows<'_>,
    vl: Rows<'_>,
    t: usize,
    hd: usize,
    n_heads: usize,
    scale: f32,
    scores: &mut Vec<f32>,
    tile: &mut Vec<f32>,
    out: &mut [f32],
) {
    let d = n_heads * hd;
    scores.clear();
    scores.resize(n_heads * t, 0.0);
    kl.for_each_block(t, tile, |start, block, rows| {
        for r in 0..rows {
            let krow = &block[r * d..(r + 1) * d];
            for hh in 0..n_heads {
                let qh = &q_row[hh * hd..(hh + 1) * hd];
                let kh = &krow[hh * hd..(hh + 1) * hd];
                scores[hh * t + start + r] =
                    qh.iter().zip(kh.iter()).map(|(x, y)| x * y).sum::<f32>() * scale;
            }
        }
    });
    for hh in 0..n_heads {
        ops::softmax_inplace(&mut scores[hh * t..(hh + 1) * t]);
    }
    out.fill(0.0);
    vl.for_each_block(t, tile, |start, block, rows| {
        for r in 0..rows {
            let vrow = &block[r * d..(r + 1) * d];
            for hh in 0..n_heads {
                let a = scores[hh * t + start + r];
                let o = &mut out[hh * hd..(hh + 1) * hd];
                let vh = &vrow[hh * hd..(hh + 1) * hd];
                for (oo, &vv) in o.iter_mut().zip(vh.iter()) {
                    *oo += a * vv;
                }
            }
        }
    });
}

/// Index of the maximum logit (first on ties).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nano() -> NativeConfig {
        NativeConfig::named("nano").unwrap()
    }

    #[test]
    fn decode_produces_finite_logits_all_formats() {
        let cfg = nano();
        let weights = random_weights(&cfg, 0);
        for format in Format::ALL {
            let model = TernaryModel::build(cfg, &weights, format);
            let mut cache = KvCache::new(&cfg);
            let mut scratch = Scratch::default();
            let logits = model.forward_one(1, &mut cache, &mut scratch);
            assert_eq!(logits.len(), cfg.vocab_size);
            assert!(logits.iter().all(|x| x.is_finite()), "{format:?}");
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let cfg = nano();
        let weights = random_weights(&cfg, 1);
        let model = TernaryModel::build(cfg, &weights, Format::Sherry);
        let mut scratch = Scratch::default();
        let mut c1 = KvCache::new(&cfg);
        let g1 = model.generate(&[1, 2, 3], 16, &mut c1, &mut scratch);
        let mut c2 = KvCache::new(&cfg);
        let g2 = model.generate(&[1, 2, 3], 16, &mut c2, &mut scratch);
        assert_eq!(g1, g2);
        assert_eq!(g1.len(), 16);
    }

    #[test]
    fn kv_cache_grows_and_clears() {
        let cfg = nano();
        let weights = random_weights(&cfg, 2);
        let model = TernaryModel::build(cfg, &weights, Format::I2S);
        let mut cache = KvCache::new(&cfg);
        let mut scratch = Scratch::default();
        model.forward_one(5, &mut cache, &mut scratch);
        model.forward_one(6, &mut cache, &mut scratch);
        assert_eq!(cache.len, 2);
        assert_eq!(cache.bytes(), 2 * 2 * 2 * cfg.d_model * 4);
        cache.clear();
        assert_eq!(cache.len, 0);
        assert_eq!(cache.bytes(), 0);
    }

    #[test]
    fn model_size_ordering_matches_table4() {
        let cfg = nano();
        let weights = random_weights(&cfg, 3);
        let sizes: Vec<usize> = Format::ALL
            .iter()
            .map(|&f| TernaryModel::build(cfg, &weights, f).bytes())
            .collect();
        // Format::ALL = [Dense, I2S, Tl2, Sherry]
        assert!(sizes[0] > sizes[1], "dense > i2s");
        assert!(sizes[1] > sizes[2], "i2s > tl2");
        assert!(sizes[2] > sizes[3], "tl2 > sherry");
    }

    #[test]
    fn forward_batch_matches_independent_streams_bit_for_bit() {
        // Three sequences with different prompts and lengths, decoded
        // (a) one stream at a time via forward_one and (b) fused via
        // forward_batch — logits must be identical, which is what makes
        // continuous batching invisible to request determinism.
        let cfg = nano();
        let weights = random_weights(&cfg, 9);
        let prompts: [&[u32]; 3] = [&[1, 2, 3, 4], &[9, 8], &[5, 5, 5]];
        let pool = crate::util::ThreadPool::new(2);
        for format in Format::ALL {
            let model = TernaryModel::build(cfg, &weights, format);
            let mut scratch = Scratch::default();
            // (a) independent streams
            let mut solo_caches: Vec<KvCache> = prompts.iter().map(|_| KvCache::new(&cfg)).collect();
            let mut solo_logits: Vec<Vec<f32>> = Vec::new();
            for (p, cache) in prompts.iter().zip(&mut solo_caches) {
                let mut logits = Vec::new();
                for &t in *p {
                    logits = model.forward_one(t, cache, &mut scratch);
                }
                solo_logits.push(logits);
            }
            // (b) batched: replay the same prompts position by position
            // over the ragged active set (like the server's prefill).
            let mut caches: Vec<KvCache> = prompts.iter().map(|_| KvCache::new(&cfg)).collect();
            let mut last: Vec<Vec<f32>> = vec![Vec::new(); prompts.len()];
            let max_len = prompts.iter().map(|p| p.len()).max().unwrap();
            for step in 0..max_len {
                let sel: Vec<usize> =
                    (0..prompts.len()).filter(|&i| step < prompts[i].len()).collect();
                let toks: Vec<u32> = sel.iter().map(|&i| prompts[i][step]).collect();
                let mut refs: Vec<&mut KvCache> = Vec::new();
                let mut rest: &mut [KvCache] = &mut caches;
                let mut taken = 0usize;
                for &i in &sel {
                    let (_, tail) = rest.split_at_mut(i - taken);
                    let (head, tail) = tail.split_at_mut(1);
                    refs.push(&mut head[0]);
                    rest = tail;
                    taken = i + 1;
                }
                let logits = model.forward_batch(&toks, &mut refs, &mut scratch, Some(&pool));
                for (row, &i) in sel.iter().enumerate() {
                    last[i] = logits.row(row).to_vec();
                }
            }
            for (i, (a, b)) in last.iter().zip(&solo_logits).enumerate() {
                assert_eq!(a, b, "{format:?} seq {i}");
                assert_eq!(caches[i].len, prompts[i].len());
            }
        }
    }

    #[test]
    fn sherry_decode_close_to_dense_of_same_quant() {
        // Same Sherry ternarization served via LUT vs dequantized-dense
        // must agree closely (numeric path differs only in summation
        // order).
        let cfg = nano();
        let weights = random_weights(&cfg, 4);
        let m_lut = TernaryModel::build(cfg, &weights, Format::Sherry);
        let mut scratch = Scratch::default();
        let mut cache = KvCache::new(&cfg);
        let l1 = m_lut.forward_one(7, &mut cache, &mut scratch);
        // dense path with sherry-quantized weights
        let mut dq = ModelWeights::new();
        for (k, v) in &weights {
            let is_linear = k.contains(".w") && !k.contains("norm");
            if is_linear {
                let q = crate::quant::quantize(v, crate::quant::Method::Sherry34, crate::quant::Granularity::PerChannel);
                dq.insert(k.clone(), q.dequant());
            } else {
                dq.insert(k.clone(), v.clone());
            }
        }
        let m_dense = TernaryModel::build(cfg, &dq, Format::Dense);
        let mut cache2 = KvCache::new(&cfg);
        let l2 = m_dense.forward_one(7, &mut cache2, &mut scratch);
        for (a, b) in l1.iter().zip(&l2) {
            assert!((a - b).abs() < 2e-2 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }
}
