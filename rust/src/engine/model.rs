//! Native ternary transformer inference with KV cache — the end-to-end
//! token-generation path measured in Table 4, mirroring the Layer-2
//! architecture (`python/compile/model.py`) exactly so QAT checkpoints
//! serve natively.
//!
//! Embedding and LM head stay float (the paper quantizes "all linear
//! layers within the Transformer architecture"; BitNet-style models keep
//! embed/head in high precision).

use std::collections::BTreeMap;

use super::kernel::Scratch;
use super::linear::QuantLinear;
use super::lut;
use crate::cache::{KBlock, KvBatch, Rows, VBlock};
use crate::pack::Format;
use crate::tensor::{ops, Mat};
use crate::util::{BufferPool, Pcg64, ThreadPool};

/// Architecture hyper-parameters (keep in sync with
/// `python/compile/model.py::CONFIGS`).
#[derive(Clone, Copy, Debug)]
pub struct NativeConfig {
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
}

impl NativeConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Named presets matching the Python side.
    pub fn named(name: &str) -> Option<Self> {
        match name {
            "nano" => Some(Self { vocab_size: 256, d_model: 128, n_layers: 2, n_heads: 4, d_ff: 384, seq_len: 64 }),
            "micro" => Some(Self { vocab_size: 512, d_model: 256, n_layers: 4, n_heads: 4, d_ff: 768, seq_len: 128 }),
            "e2e" => Some(Self { vocab_size: 1024, d_model: 384, n_layers: 6, n_heads: 6, d_ff: 1152, seq_len: 128 }),
            // Paper-scale layer shapes for Table 4 benchmarking (vocab
            // truncated: the bench measures the transformer stack).
            "bench700m" => Some(Self { vocab_size: 4096, d_model: 1536, n_layers: 24, n_heads: 16, d_ff: 4096, seq_len: 256 }),
            "bench3b" => Some(Self { vocab_size: 4096, d_model: 3200, n_layers: 26, n_heads: 32, d_ff: 8640, seq_len: 256 }),
            _ => None,
        }
    }
}

/// Float parameter set (as trained / initialized), keyed by the Layer-2
/// names in `{cfg}.params.tsv`.
pub type ModelWeights = BTreeMap<String, Mat>;

/// Random-initialized weights (benches and smoke tests).
pub fn random_weights(cfg: &NativeConfig, seed: u64) -> ModelWeights {
    let mut rng = Pcg64::seeded(seed);
    let mut w = ModelWeights::new();
    let d = cfg.d_model;
    w.insert("embed".into(), Mat::randn(&mut rng, cfg.vocab_size, d, (d as f32).powf(-0.5)));
    for i in 0..cfg.n_layers {
        let p = format!("layer{i}.");
        w.insert(format!("{p}norm_attn"), Mat::from_vec(1, d, vec![1.0; d]));
        w.insert(format!("{p}norm_mlp"), Mat::from_vec(1, d, vec![1.0; d]));
        for (name, rows, cols) in [
            ("wq", d, d),
            ("wk", d, d),
            ("wv", d, d),
            ("wo", d, d),
            ("w_gate", d, cfg.d_ff),
            ("w_up", d, cfg.d_ff),
            ("w_down", cfg.d_ff, d),
        ] {
            w.insert(format!("{p}{name}"), Mat::randn(&mut rng, rows, cols, (rows as f32).powf(-0.5)));
        }
    }
    w.insert("norm_out".into(), Mat::from_vec(1, d, vec![1.0; d]));
    w.insert("lm_head".into(), Mat::randn(&mut rng, d, cfg.vocab_size, (d as f32).powf(-0.5)));
    w
}

struct Layer {
    norm_attn: Vec<f32>,
    norm_mlp: Vec<f32>,
    wq: QuantLinear,
    wk: QuantLinear,
    wv: QuantLinear,
    wo: QuantLinear,
    w_gate: QuantLinear,
    w_up: QuantLinear,
    w_down: QuantLinear,
}

/// Per-sequence contiguous KV cache — the degenerate single-table case
/// of the paged subsystem (`crate::cache`): single-stream paths (eval,
/// [`TernaryModel::generate`]) keep this dense layout, while the serving
/// coordinator decodes through paged [`BlockTable`]s. Both feed the same
/// [`KvBatch`] view, so the numeric path is identical.
///
/// [`BlockTable`]: crate::cache::BlockTable
pub struct KvCache {
    /// `[layer][pos * d_model + c]`
    pub(crate) k: Vec<Vec<f32>>,
    pub(crate) v: Vec<Vec<f32>>,
    pub len: usize,
    /// Model width (for external byte accounting).
    pub d_model: usize,
}

impl KvCache {
    pub fn new(cfg: &NativeConfig) -> Self {
        let cap = cfg.seq_len * cfg.d_model;
        Self {
            k: (0..cfg.n_layers).map(|_| Vec::with_capacity(cap)).collect(),
            v: (0..cfg.n_layers).map(|_| Vec::with_capacity(cap)).collect(),
            len: 0,
            d_model: cfg.d_model,
        }
    }

    pub fn clear(&mut self) {
        for k in &mut self.k {
            k.clear();
        }
        for v in &mut self.v {
            v.clear();
        }
        self.len = 0;
    }

    /// Approximate resident bytes (metrics / KV pool accounting).
    pub fn bytes(&self) -> usize {
        self.k.iter().chain(self.v.iter()).map(|b| b.len() * 4).sum()
    }
}

/// The native quantized transformer.
pub struct TernaryModel {
    pub cfg: NativeConfig,
    pub format: Format,
    embed: Mat,
    layers: Vec<Layer>,
    norm_out: Vec<f32>,
    lm_head: QuantLinear,
    /// Leased f32 scratch for the page-blocked attention walk (score
    /// rows, dequantized KV blocks, query scales), reused across rounds.
    tiles: BufferPool,
    /// Leased int8 scratch for query quantization on the int8-native
    /// score path — leased once per (sequence, decode round) and reused
    /// by every layer's attention pass, so there is no per-call heap
    /// allocation *or* per-layer pool round-trip.
    qcodes: BufferPool<i8>,
    /// Leased u8 scratch for the fixed-point a·V pass: softmax weights
    /// quantized per (page, head) to `[0, 127]` codes. Same lease
    /// cadence as `qcodes`.
    wcodes: BufferPool<u8>,
    /// Leased i32 scratch for the fixed-point a·V pass: one head-wide
    /// integer channel accumulator.
    iacc: BufferPool<i32>,
}

impl TernaryModel {
    /// Build from float weights, quantizing every transformer linear into
    /// `format` (embed + lm_head stay float/dense).
    pub fn build(cfg: NativeConfig, weights: &ModelWeights, format: Format) -> Self {
        let get = |name: &str| weights.get(name).unwrap_or_else(|| panic!("missing weight {name}"));
        let layers = (0..cfg.n_layers)
            .map(|i| {
                let p = format!("layer{i}.");
                Layer {
                    norm_attn: get(&format!("{p}norm_attn")).data.clone(),
                    norm_mlp: get(&format!("{p}norm_mlp")).data.clone(),
                    wq: QuantLinear::from_float(get(&format!("{p}wq")), format),
                    wk: QuantLinear::from_float(get(&format!("{p}wk")), format),
                    wv: QuantLinear::from_float(get(&format!("{p}wv")), format),
                    wo: QuantLinear::from_float(get(&format!("{p}wo")), format),
                    w_gate: QuantLinear::from_float(get(&format!("{p}w_gate")), format),
                    w_up: QuantLinear::from_float(get(&format!("{p}w_up")), format),
                    w_down: QuantLinear::from_float(get(&format!("{p}w_down")), format),
                }
            })
            .collect();
        Self {
            cfg,
            format,
            embed: get("embed").clone(),
            layers,
            norm_out: get("norm_out").data.clone(),
            lm_head: QuantLinear::from_float(get("lm_head"), Format::Dense),
            tiles: BufferPool::new(),
            qcodes: BufferPool::new(),
            wcodes: BufferPool::new(),
            iacc: BufferPool::new(),
        }
    }

    /// Build with an explicit quantization *method* (PTQ of QAT-trained
    /// latents — the deployed-model path of the eval harness). Sherry
    /// serves through the packed LUT engine; every other method serves
    /// its dequantized weights densely (their packings don't affect
    /// accuracy, only speed, which Table 4 measures separately).
    pub fn build_ptq(
        cfg: NativeConfig,
        weights: &ModelWeights,
        method: crate::quant::Method,
        granularity: crate::quant::Granularity,
    ) -> Self {
        use crate::quant::{quantize, Method};
        let mut q_weights = ModelWeights::new();
        for (name, w) in weights {
            let is_linear = name.contains("layer") && !name.contains("norm") && !name.ends_with(".aux");
            if is_linear {
                let q = quantize(w, method, granularity);
                q_weights.insert(name.clone(), q.dequant());
            } else if !name.ends_with(".aux") {
                q_weights.insert(name.clone(), w.clone());
            }
        }
        let format = if method == Method::Sherry34
            && matches!(granularity, crate::quant::Granularity::PerChannel)
        {
            // Serve Sherry through the real 1.25-bit LUT path.
            let mut m = Self::build(cfg, weights, Format::Sherry);
            // norms/embed/head come from `weights` already; done.
            m.format = Format::Sherry;
            return m;
        } else {
            Format::Dense
        };
        Self::build(cfg, &q_weights, format)
    }

    /// Total model bytes (quantized linears + float embed/head/norms) —
    /// the Table 4 "Size (MB)" column.
    pub fn bytes(&self) -> usize {
        let mut b = self.embed.data.len() * 2 + self.norm_out.len() * 2; // bf16 floats
        b += self.lm_head.bytes();
        for l in &self.layers {
            b += (l.norm_attn.len() + l.norm_mlp.len()) * 2;
            for lin in [&l.wq, &l.wk, &l.wv, &l.wo, &l.w_gate, &l.w_up, &l.w_down] {
                b += lin.bytes();
            }
        }
        b
    }

    /// One decode step: feed `token` at position `cache.len`, return
    /// logits. Thin `batch = 1` wrapper over [`TernaryModel::forward_batch`]
    /// — single-stream and batched decoding are the same code path, so a
    /// sequence's logits do not depend on who it shares a round with.
    pub fn forward_one(&self, token: u32, cache: &mut KvCache, scratch: &mut Scratch) -> Vec<f32> {
        self.forward_batch(&[token], &mut [cache], scratch, None).data
    }

    /// One batched decode step across `tokens.len()` sequences, each with
    /// its own KV cache (sequences may sit at different positions — the
    /// continuous-batching case). Appends one K/V row per sequence per
    /// layer and returns the `batch × vocab` logits.
    ///
    /// Every linear goes through one fused [`kernel
    /// gemm_nt`](crate::engine::TernaryKernel::gemm_nt): activation LUTs
    /// for the whole batch are built once per layer input, then each
    /// packed weight plane is walked a single time with all LUTs resident,
    /// fanned out over output-channel tiles on `pool`. Attention, norms
    /// and the SwiGLU are applied per sequence row (identical scalar code
    /// to the single-stream path).
    pub fn forward_batch(
        &self,
        tokens: &[u32],
        caches: &mut [&mut KvCache],
        scratch: &mut Scratch,
        pool: Option<&ThreadPool>,
    ) -> Mat {
        let mut kv = KvBatch::Contig(caches);
        self.forward_kv(tokens, &mut kv, scratch, pool)
    }

    /// One batched decode step through a [`KvBatch`] storage view —
    /// contiguous caches and the paged block-table arena run this same
    /// code, so paged serving is bit-for-bit identical to the contiguous
    /// baseline (DESIGN.md §4).
    pub fn forward_kv(
        &self,
        tokens: &[u32],
        kv: &mut KvBatch<'_, '_>,
        scratch: &mut Scratch,
        pool: Option<&ThreadPool>,
    ) -> Mat {
        let b = tokens.len();
        assert_eq!(kv.batch(), b, "one KV backing per sequence");
        let cfg = &self.cfg;
        let d = cfg.d_model;
        let hd = cfg.head_dim();
        // Per-sequence decode positions (continuous batching: they differ).
        let pos: Vec<usize> = (0..b).map(|i| kv.pos(i)).collect();
        for &p in &pos {
            // Contract with the coordinator: a sequence at the context
            // limit must be finished with FinishReason::ContextLimit, not
            // fed — see coordinator/server.rs planning.
            assert!(p < cfg.seq_len, "decode position {p} past context limit {}", cfg.seq_len);
        }
        // Paged backing: allocate / copy-on-write each sequence's next
        // slot once, before any layer writes or reads.
        kv.begin_step();

        let mut h = vec![0.0f32; b * d];
        for (bi, &tok) in tokens.iter().enumerate() {
            h[bi * d..(bi + 1) * d].copy_from_slice(self.embed.row(tok as usize));
        }

        let mut xn = vec![0.0f32; b * d];
        let mut q = vec![0.0f32; b * d];
        let mut k = vec![0.0f32; b * d];
        let mut v = vec![0.0f32; b * d];
        let mut att_out = vec![0.0f32; b * d];
        let mut proj = vec![0.0f32; b * d];
        let mut gate = vec![0.0f32; b * cfg.d_ff];
        let mut up = vec![0.0f32; b * cfg.d_ff];
        let scale = (hd as f32).powf(-0.5);

        // Attention scratch: one lease set per sequence slot for the whole
        // decode round, re-borrowed by every layer's attention pass
        // (`attention_blocked` clears and refills per call). Previously
        // each (layer, sequence) attention call leased and returned four
        // buffers — n_layers× more pool lock traffic, and the
        // query-quantization buffers churned per call.
        let mut attn_scratch: Vec<AttnScratch> = (0..b)
            .map(|_| AttnScratch {
                scores: self.tiles.lease(),
                tile: self.tiles.lease(),
                q_scales: self.tiles.lease(),
                q_luts: self.tiles.lease(),
                q_codes: self.qcodes.lease(),
                a_codes: self.wcodes.lease(),
                acc: self.iacc.lease(),
            })
            .collect();

        for (li, layer) in self.layers.iter().enumerate() {
            // --- attention block ---
            xn.copy_from_slice(&h);
            for bi in 0..b {
                ops::rmsnorm_inplace(&mut xn[bi * d..(bi + 1) * d], &layer.norm_attn);
            }
            layer.wq.forward_batch(&xn, &mut q, b, scratch, pool);
            layer.wk.forward_batch(&xn, &mut k, b, scratch, pool);
            layer.wv.forward_batch(&xn, &mut v, b, scratch, pool);
            for bi in 0..b {
                // RoPE per head (matches L2: per-head half-pairing).
                for hh in 0..cfg.n_heads {
                    ops::rope_inplace(&mut q[bi * d + hh * hd..bi * d + (hh + 1) * hd], pos[bi]);
                    ops::rope_inplace(&mut k[bi * d + hh * hd..bi * d + (hh + 1) * hd], pos[bi]);
                }
                kv.append(li, bi, &k[bi * d..(bi + 1) * d], &v[bi * d..(bi + 1) * d]);
            }
            // Per-sequence attention over each sequence's own KV history —
            // independent across sequences, so it fans out on the pool
            // alongside the fused linears. The walk is page-blocked and
            // dtype-native: the score pass consumes int8 pages as raw
            // bytes (i32 q·k dots, one scale multiply per page-head) and
            // f32 pages as borrowed tiles; the V pass materializes each
            // page at most once as f32 (frozen prefix pages via the
            // store's shared tile cache, private pages into a leased
            // scratch tile). Per-element float ops and their order on the
            // f32 arm are identical to the old position-at-a-time walk,
            // preserving bit-for-bit parity for f32 storage.
            {
                let kv_ro: &KvBatch = kv;
                let n_heads = cfg.n_heads;
                match pool {
                    Some(pool) if b > 1 => pool.scope(|s| {
                        for ((bi, out_row), scr) in
                            att_out.chunks_mut(d).enumerate().zip(attn_scratch.iter_mut())
                        {
                            let kl = kv_ro.k_rows(li, bi);
                            let vl = kv_ro.v_rows(li, bi);
                            let q_row = &q[bi * d..(bi + 1) * d];
                            let t = pos[bi] + 1;
                            s.spawn(move || {
                                attention_blocked(
                                    q_row, kl, vl, t, hd, n_heads, scale, &mut scr.scores,
                                    &mut scr.tile, &mut scr.q_codes, &mut scr.q_scales,
                                    &mut scr.q_luts, &mut scr.a_codes, &mut scr.acc, out_row,
                                );
                            });
                        }
                    }),
                    _ => {
                        for ((bi, out_row), scr) in
                            att_out.chunks_mut(d).enumerate().zip(attn_scratch.iter_mut())
                        {
                            let kl = kv_ro.k_rows(li, bi);
                            let vl = kv_ro.v_rows(li, bi);
                            let q_row = &q[bi * d..(bi + 1) * d];
                            attention_blocked(
                                q_row, kl, vl, pos[bi] + 1, hd, n_heads, scale, &mut scr.scores,
                                &mut scr.tile, &mut scr.q_codes, &mut scr.q_scales,
                                &mut scr.q_luts, &mut scr.a_codes, &mut scr.acc, out_row,
                            );
                        }
                    }
                }
            }
            layer.wo.forward_batch(&att_out, &mut proj, b, scratch, pool);
            for (hi, &p) in h.iter_mut().zip(proj.iter()) {
                *hi += p;
            }

            // --- MLP block (SwiGLU) ---
            xn.copy_from_slice(&h);
            for bi in 0..b {
                ops::rmsnorm_inplace(&mut xn[bi * d..(bi + 1) * d], &layer.norm_mlp);
            }
            layer.w_gate.forward_batch(&xn, &mut gate, b, scratch, pool);
            layer.w_up.forward_batch(&xn, &mut up, b, scratch, pool);
            for (g, &u) in gate.iter_mut().zip(up.iter()) {
                let s = *g;
                *g = s / (1.0 + (-s).exp()) * u; // silu(g) * u
            }
            layer.w_down.forward_batch(&gate, &mut proj, b, scratch, pool);
            for (hi, &p) in h.iter_mut().zip(proj.iter()) {
                *hi += p;
            }
        }
        kv.advance();
        for scr in attn_scratch.drain(..) {
            self.iacc.give(scr.acc);
            self.wcodes.give(scr.a_codes);
            self.qcodes.give(scr.q_codes);
            self.tiles.give(scr.q_luts);
            self.tiles.give(scr.q_scales);
            self.tiles.give(scr.tile);
            self.tiles.give(scr.scores);
        }

        for bi in 0..b {
            ops::rmsnorm_inplace(&mut h[bi * d..(bi + 1) * d], &self.norm_out);
        }
        let mut logits = vec![0.0f32; b * cfg.vocab_size];
        self.lm_head.forward_batch(&h, &mut logits, b, scratch, pool);
        Mat::from_vec(b, cfg.vocab_size, logits)
    }

    /// Greedy-generate `n_tokens` starting from `prompt`. Returns the
    /// generated ids (prompt excluded).
    pub fn generate(&self, prompt: &[u32], n_tokens: usize, cache: &mut KvCache, scratch: &mut Scratch) -> Vec<u32> {
        cache.clear();
        let mut logits = Vec::new();
        for &tok in prompt {
            logits = self.forward_one(tok, cache, scratch);
        }
        let mut out = Vec::with_capacity(n_tokens);
        let mut next = argmax(&logits) as u32;
        for _ in 0..n_tokens {
            out.push(next);
            if cache.len >= self.cfg.seq_len {
                break;
            }
            logits = self.forward_one(next, cache, scratch);
            next = argmax(&logits) as u32;
        }
        out
    }
}

/// One sequence slot's attention scratch, leased from the model's pools
/// once per decode round (see [`TernaryModel::forward_kv`]) and
/// re-borrowed by every layer's [`attention_blocked`] call.
struct AttnScratch {
    scores: Vec<f32>,
    tile: Vec<f32>,
    q_scales: Vec<f32>,
    q_luts: Vec<f32>,
    q_codes: Vec<i8>,
    /// Per-(page, head) u8 softmax-weight codes for the fixed-point a·V
    /// pass.
    a_codes: Vec<u8>,
    /// Head-wide i32 channel accumulator for the fixed-point a·V pass.
    acc: Vec<i32>,
}

/// Int8-quantize one query row per head into caller buffers (leased
/// from the model's pools — no per-call heap allocation): `codes` gets
/// `n_heads × head_dim` symmetric round-to-nearest codes in ±127,
/// `scales[h] = absmax(q_h) / 127` (an all-zero head keeps scale 0 and
/// zero codes). Done once per [`attention_blocked`] call — "once per
/// (head, round)" — and only when the K history is quantized (int8 or
/// 1.25-bit ternary), so the f32 path never pays for it.
fn quantize_query(
    q_row: &[f32],
    n_heads: usize,
    hd: usize,
    codes: &mut Vec<i8>,
    scales: &mut Vec<f32>,
) {
    codes.clear();
    codes.resize(n_heads * hd, 0);
    scales.clear();
    scales.resize(n_heads, 0.0);
    for hh in 0..n_heads {
        let h0 = hh * hd;
        let absmax = q_row[h0..h0 + hd].iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        if absmax == 0.0 {
            continue;
        }
        let s = absmax / 127.0;
        scales[hh] = s;
        for c in 0..hd {
            codes[h0 + c] = (q_row[h0 + c] / s).round().clamp(-127.0, 127.0) as i8;
        }
    }
}

/// Page-blocked causal attention for one sequence at its current decode
/// position, writing the `d_model`-wide output row. One shared body for
/// the serial and pool-fanned paths of [`TernaryModel::forward_kv`].
///
/// Three passes over `t` cached timesteps: (1) every head's query·key
/// dot products into `scores` (`n_heads × t`), (2) per-head softmax,
/// (3) weighted-V accumulation. The score pass walks the K history via
/// [`Rows::for_each_kblock`], so quantized pages are consumed **at their
/// storage dtype**: the query is quantized once per call
/// ([`quantize_query`]); int8 pages then contribute i32 integer dots and
/// 1.25-bit ternary pages contribute per-query LUT walks over their
/// packed pack34 codes ([`crate::simd::qk_lut34_rows_with`], tables
/// built once per call by [`lut::build_qk_luts34`]) — either way scaled
/// by one `q_scale · page_head_scale` product per (page, head), and the
/// K plane is never dequantized. The V pass walks
/// [`Rows::for_each_vblock`], which yields quantized pages as raw int8
/// bytes: the softmax weights for each (page, head) group are quantized
/// to u8 fixed point in one explicit rounding step (`s_a = max/127`,
/// codes in `[0, 127]`), [`crate::simd::av_i8_rows_with`] accumulates
/// `â·V̂` in exact i32 across the head's channels, and one `s_a · s_v`
/// multiply per (page, head) folds both scales back in — V is never
/// dequantized either, so for quantized stores a decode round touches
/// no f32 K or V page bytes at all (DESIGN.md §4 derives the bound).
/// f32 pages (and quantized stores with integer-V disabled) take the
/// [`VBlock::F32`] arm: registration-frozen pages served from the
/// store's shared LRU tile cache, private pages dequantized once into
/// `tile`. A page is materialized at most once per pass and reused for
/// every dot product / accumulation that touches it — the same
/// amortization `gemm_nt` applies to weight planes.
///
/// f32 storage takes the [`KBlock::F32`] arm whose per-element float ops
/// and ordering match the old position-at-a-time walk exactly, so f32
/// pages (paged or contiguous) remain **bit-for-bit identical** to the
/// pre-blocked kernel; the int8 fused dot and the ternary LUT walk are
/// deterministic and within the error bounds derived in DESIGN.md §4.
#[allow(clippy::too_many_arguments)]
fn attention_blocked(
    q_row: &[f32],
    kl: Rows<'_>,
    vl: Rows<'_>,
    t: usize,
    hd: usize,
    n_heads: usize,
    scale: f32,
    scores: &mut Vec<f32>,
    tile: &mut Vec<f32>,
    q_codes: &mut Vec<i8>,
    q_scales: &mut Vec<f32>,
    q_luts: &mut Vec<f32>,
    a_codes: &mut Vec<u8>,
    acc: &mut Vec<i32>,
    out: &mut [f32],
) {
    let d = n_heads * hd;
    // Pin the kernel ISA once per call; the per-(row, head) dot below
    // dispatches without re-reading the process-global selection.
    let isa = crate::simd::active();
    scores.clear();
    scores.resize(n_heads * t, 0.0);
    // Leased query-quantization buffers; emptied here, filled lazily on
    // the first quantized K block (the f32 path never quantizes q, and
    // the q·k LUTs are only built when a ternary page shows up).
    q_codes.clear();
    q_scales.clear();
    q_luts.clear();
    let (mut native_rows, mut dequant_rows, mut ternary_rows) = (0u64, 0u64, 0u64);
    // Each match arm opens a `KernelSpan` over its whole page block (one
    // Instant pair per block at `--trace kernels`, one relaxed load and
    // no clock reads below it) — tracing never touches the numerics.
    kl.for_each_kblock(t, tile, |start, block, rows| match block {
        KBlock::F32(block) => {
            let _k = crate::obs::KernelSpan::enter(crate::obs::Kernel::QkF32);
            for r in 0..rows {
                let krow = &block[r * d..(r + 1) * d];
                for hh in 0..n_heads {
                    let qh = &q_row[hh * hd..(hh + 1) * hd];
                    let kh = &krow[hh * hd..(hh + 1) * hd];
                    scores[hh * t + start + r] =
                        qh.iter().zip(kh.iter()).map(|(x, y)| x * y).sum::<f32>() * scale;
                }
            }
            dequant_rows += rows as u64;
        }
        KBlock::I8 { data, scales } => {
            let _k = crate::obs::KernelSpan::enter(crate::obs::Kernel::QkDotI8);
            if q_codes.is_empty() {
                quantize_query(q_row, n_heads, hd, q_codes, q_scales);
            }
            for r in 0..rows {
                let krow = &data[r * d..(r + 1) * d];
                for hh in 0..n_heads {
                    let qh = &q_codes[hh * hd..(hh + 1) * hd];
                    let kh = &krow[hh * hd..(hh + 1) * hd];
                    // |acc| ≤ 127² · head_dim ≪ i32::MAX for any real
                    // head width; one f32 multiply per (page, head, row)
                    // folds both scales back in. i32 accumulation is
                    // associative, so the vector paths are bit-exact.
                    let acc: i32 = crate::simd::dot_i8_with(isa, qh, kh);
                    scores[hh * t + start + r] = acc as f32 * (q_scales[hh] * scales[hh]) * scale;
                }
            }
            native_rows += rows as u64;
        }
        KBlock::Ternary(tb) => {
            let _k = crate::obs::KernelSpan::enter(crate::obs::Kernel::QkLut34);
            if q_codes.is_empty() {
                quantize_query(q_row, n_heads, hd, q_codes, q_scales);
            }
            if q_luts.is_empty() {
                q_luts.resize(n_heads * (hd / 4) * 32, 0.0);
                lut::build_qk_luts34(q_codes, hd, n_heads, q_luts);
            }
            let nb = hd / 4;
            for hh in 0..n_heads {
                // The walk writes the raw integer q̂·k̂ sums (exact in f32;
                // see `lut::build_qk_luts34`), then one multiply per row
                // folds both quantizer scales and the softmax scale back
                // in — K stays packed end to end.
                crate::simd::qk_lut34_rows_with(
                    isa, tb.idx, tb.sign, tb.idx_bh, tb.sign_bh, nb, hh, n_heads, q_luts,
                    rows, &mut scores[hh * t + start..hh * t + start + rows],
                );
                let s = q_scales[hh] * tb.scales[hh] * scale;
                for v in &mut scores[hh * t + start..hh * t + start + rows] {
                    *v *= s;
                }
            }
            ternary_rows += rows as u64;
        }
    });
    kl.record_qk(native_rows, dequant_rows, ternary_rows);
    for hh in 0..n_heads {
        ops::softmax_inplace(&mut scores[hh * t..(hh + 1) * t]);
    }
    out.fill(0.0);
    let mut av_int8 = 0u64;
    vl.for_each_vblock(t, tile, |start, block, rows| match block {
        VBlock::F32(block) => {
            let _k = crate::obs::KernelSpan::enter(crate::obs::Kernel::AvF32);
            for r in 0..rows {
                let vrow = &block[r * d..(r + 1) * d];
                for hh in 0..n_heads {
                    let a = scores[hh * t + start + r];
                    let o = &mut out[hh * hd..(hh + 1) * hd];
                    let vh = &vrow[hh * hd..(hh + 1) * hd];
                    for (oo, &vv) in o.iter_mut().zip(vh.iter()) {
                        *oo += a * vv;
                    }
                }
            }
        }
        VBlock::I8 { data, scales } => {
            let _k = crate::obs::KernelSpan::enter(crate::obs::Kernel::AvI8);
            a_codes.clear();
            a_codes.resize(rows, 0);
            acc.clear();
            acc.resize(hd, 0);
            for hh in 0..n_heads {
                let w = &scores[hh * t + start..hh * t + start + rows];
                // Quantize this (page, head) weight group to u8 fixed
                // point in one explicit rounding step: the group is
                // exactly the rows one page contributes to one head's
                // softmax, so s_a = max/127 is the exact absmax scale
                // (softmax weights are nonnegative) and codes stay in
                // [0, 127] — products fit i16 and i32 sums are exact.
                let max = w.iter().fold(0.0f32, |m, &x| m.max(x));
                if max <= 0.0 || scales[hh] == 0.0 {
                    // All-zero weights or an all-zero V head contribute
                    // nothing; skipping keeps s_a well-defined.
                    continue;
                }
                let s_a = max / 127.0;
                for (c, &x) in a_codes.iter_mut().zip(w) {
                    *c = (x / s_a).round().clamp(0.0, 127.0) as u8;
                }
                crate::simd::av_i8_rows_with(isa, a_codes, data, d, hh * hd, hd, rows, acc);
                // One f32 multiply per (page, head) folds the weight and
                // V quantizer scales back in.
                let s = s_a * scales[hh];
                let o = &mut out[hh * hd..(hh + 1) * hd];
                for (oo, &ai) in o.iter_mut().zip(acc.iter()) {
                    *oo += ai as f32 * s;
                }
            }
            av_int8 += rows as u64;
        }
    });
    vl.record_av(av_int8);
}

/// Index of the maximum logit (first on ties).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nano() -> NativeConfig {
        NativeConfig::named("nano").unwrap()
    }

    #[test]
    fn decode_produces_finite_logits_all_formats() {
        let cfg = nano();
        let weights = random_weights(&cfg, 0);
        for format in Format::ALL {
            let model = TernaryModel::build(cfg, &weights, format);
            let mut cache = KvCache::new(&cfg);
            let mut scratch = Scratch::default();
            let logits = model.forward_one(1, &mut cache, &mut scratch);
            assert_eq!(logits.len(), cfg.vocab_size);
            assert!(logits.iter().all(|x| x.is_finite()), "{format:?}");
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let cfg = nano();
        let weights = random_weights(&cfg, 1);
        let model = TernaryModel::build(cfg, &weights, Format::Sherry);
        let mut scratch = Scratch::default();
        let mut c1 = KvCache::new(&cfg);
        let g1 = model.generate(&[1, 2, 3], 16, &mut c1, &mut scratch);
        let mut c2 = KvCache::new(&cfg);
        let g2 = model.generate(&[1, 2, 3], 16, &mut c2, &mut scratch);
        assert_eq!(g1, g2);
        assert_eq!(g1.len(), 16);
    }

    #[test]
    fn quantize_query_roundtrips_within_half_quantum() {
        let cfg = nano();
        let (nh, hd) = (cfg.n_heads, cfg.head_dim());
        let mut rng = crate::util::Pcg64::seeded(41);
        let q = rng.normal_vec(cfg.d_model);
        let (mut codes, mut scales) = (Vec::new(), Vec::new());
        super::quantize_query(&q, nh, hd, &mut codes, &mut scales);
        for hh in 0..nh {
            let s = scales[hh];
            assert!(s > 0.0);
            let mut saw_full_range = false;
            for c in hh * hd..(hh + 1) * hd {
                let back = codes[c] as f32 * s;
                assert!(
                    (back - q[c]).abs() <= 0.5 * s + 1e-7,
                    "head {hh} ch {c}: {back} vs {} at scale {s}",
                    q[c]
                );
                saw_full_range |= codes[c].unsigned_abs() == 127;
            }
            assert!(saw_full_range, "the absmax element must map to ±127");
        }
        // All-zero heads keep scale 0 / zero codes (dot contributes 0),
        // and reused (leased) buffers are refilled from scratch.
        super::quantize_query(&vec![0.0; cfg.d_model], nh, hd, &mut codes, &mut scales);
        assert!(scales.iter().all(|&s| s == 0.0));
        assert!(codes.iter().all(|&c| c == 0));
    }

    #[test]
    fn int8_fused_qk_matches_dequant_scores_closely() {
        // The fused i32 dot over raw page bytes must agree with the
        // dequantize-then-f32 score path to within the query-quantization
        // error: ≤ hd · 0.5·q_scale · k_absmax per dot (DESIGN.md §4) —
        // the page bytes and scales are shared by both paths, so K-side
        // quantization error cancels entirely.
        let cfg = nano();
        let d = cfg.d_model;
        let (nh, hd) = (cfg.n_heads, cfg.head_dim());
        let mut rng = crate::util::Pcg64::seeded(43);
        let mut alloc =
            crate::cache::BlockAllocator::new_with(&cfg, 4, 4, crate::cache::KvDtype::Int8);
        let mut table = crate::cache::BlockTable::new(4);
        for pos in 0..6usize {
            table.prepare_append(&mut alloc);
            let (page, slot) = table.slot_for(pos);
            let row = rng.normal_vec(d);
            alloc.write_row(0, page, slot, &row, &row);
            table.advance();
        }
        let q = rng.normal_vec(d);
        let (mut codes, mut q_scales) = (Vec::new(), Vec::new());
        super::quantize_query(&q, nh, hd, &mut codes, &mut q_scales);
        let mut tables = [&mut table];
        let kv = KvBatch::Paged { alloc: &mut alloc, tables: &mut tables };
        let rows = kv.k_rows(0, 0);
        let mut scratch = Vec::new();
        // Reference: dequantized page bytes dotted in f32.
        let mut dequant = vec![0.0f32; nh * 6];
        rows.for_each_block(6, &mut scratch, |start, block, n| {
            for r in 0..n {
                for hh in 0..nh {
                    dequant[hh * 6 + start + r] = q[hh * hd..(hh + 1) * hd]
                        .iter()
                        .zip(&block[r * d + hh * hd..r * d + (hh + 1) * hd])
                        .map(|(x, y)| x * y)
                        .sum();
                }
            }
        });
        // Fused: i32 dots over the same bytes.
        rows.for_each_kblock(6, &mut scratch, |start, block, n| {
            let KBlock::I8 { data, scales } = block else { panic!("int8 store") };
            for r in 0..n {
                for hh in 0..nh {
                    let acc: i32 = codes[hh * hd..(hh + 1) * hd]
                        .iter()
                        .zip(&data[r * d + hh * hd..r * d + (hh + 1) * hd])
                        .map(|(&x, &y)| x as i32 * y as i32)
                        .sum();
                    let fused = acc as f32 * (q_scales[hh] * scales[hh]);
                    let reference = dequant[hh * 6 + start + r];
                    // k̂ head absmax is ≤ 127·scales[hh] by construction.
                    let bound = hd as f32 * 0.5 * q_scales[hh] * 127.0 * scales[hh] + 1e-5;
                    assert!(
                        (fused - reference).abs() <= bound,
                        "pos {} head {hh}: fused {fused} vs dequant {reference}",
                        start + r
                    );
                }
            }
        });
        table.release_all(&mut alloc);
    }

    #[test]
    fn integer_v_pass_stays_within_design_bound_elementwise() {
        // The fixed-point a·V pass must agree with the dequantize-then-f32
        // accumulation elementwise, within the DESIGN.md §4 weight-rounding
        // bound: both paths consume the same stored V codes and scales, so
        // V-side quantization error cancels and only the u8 rounding of
        // the softmax weights remains —
        //   |Δout[c]| ≤ Σ_pages ½·s_a · s_v · Σ_r |v̂_r[c]|.
        // Ternary stores share the int8 V plane, so both dtypes run the
        // same arm.
        let cfg = nano();
        let d = cfg.d_model;
        let (nh, hd) = (cfg.n_heads, cfg.head_dim());
        let t = 6usize;
        for dtype in [crate::cache::KvDtype::Int8, crate::cache::KvDtype::Ternary] {
            let mut rng = crate::util::Pcg64::seeded(53);
            let mut alloc = crate::cache::BlockAllocator::new_with(&cfg, 4, 4, dtype);
            let mut table = crate::cache::BlockTable::new(4);
            for pos in 0..t {
                table.prepare_append(&mut alloc);
                let (page, slot) = table.slot_for(pos);
                let row = rng.normal_vec(d);
                alloc.write_row(0, page, slot, &row, &row);
                table.advance();
            }
            // Realistic nonnegative attention weights: per-head softmax.
            let mut weights = vec![0.0f32; nh * t];
            for hh in 0..nh {
                let logits = rng.normal_vec(t);
                let m = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
                let mut z = 0.0f32;
                for (wv, &x) in weights[hh * t..(hh + 1) * t].iter_mut().zip(&logits) {
                    *wv = (x - m).exp();
                    z += *wv;
                }
                for wv in &mut weights[hh * t..(hh + 1) * t] {
                    *wv /= z;
                }
            }
            let mut tables = [&mut table];
            let kv = KvBatch::Paged { alloc: &mut alloc, tables: &mut tables };
            let rows_view = kv.v_rows(0, 0);
            let mut scratch = Vec::new();
            // Reference: dequantized V pages accumulated in f32.
            let mut reference = vec![0.0f32; d];
            rows_view.for_each_block(t, &mut scratch, |start, block, n| {
                for r in 0..n {
                    for hh in 0..nh {
                        let a = weights[hh * t + start + r];
                        for c in 0..hd {
                            reference[hh * hd + c] += a * block[r * d + hh * hd + c];
                        }
                    }
                }
            });
            // Fused: the attention_blocked arm — u8-quantized weight
            // group, i32 accumulate over raw bytes, one s_a·s_v fold.
            let mut fused = vec![0.0f32; d];
            let mut bound = vec![0.0f32; d];
            let mut codes: Vec<u8> = Vec::new();
            let mut acc = vec![0i32; hd];
            rows_view.for_each_vblock(t, &mut scratch, |start, block, n| {
                let VBlock::I8 { data, scales } = block else { panic!("quantized store") };
                codes.clear();
                codes.resize(n, 0);
                for hh in 0..nh {
                    let w = &weights[hh * t + start..hh * t + start + n];
                    let max = w.iter().fold(0.0f32, |m, &x| m.max(x));
                    if max <= 0.0 || scales[hh] == 0.0 {
                        continue;
                    }
                    let s_a = max / 127.0;
                    for (cd, &x) in codes.iter_mut().zip(w) {
                        *cd = (x / s_a).round().clamp(0.0, 127.0) as u8;
                    }
                    crate::simd::av_i8_rows_scalar(&codes, data, d, hh * hd, hd, n, &mut acc);
                    for c in 0..hd {
                        fused[hh * hd + c] += acc[c] as f32 * (s_a * scales[hh]);
                        let vmag: f32 =
                            (0..n).map(|r| (data[r * d + hh * hd + c] as f32).abs()).sum();
                        bound[hh * hd + c] += 0.5 * s_a * scales[hh] * vmag;
                    }
                }
            });
            for c in 0..d {
                assert!(
                    (fused[c] - reference[c]).abs() <= bound[c] + 1e-4,
                    "{dtype:?} ch {c}: fused {} vs dequant {} (bound {})",
                    fused[c],
                    reference[c],
                    bound[c]
                );
            }
            table.release_all(&mut alloc);
        }
    }

    #[test]
    fn ternary_fused_qk_stays_within_design_bounds() {
        // The LUT-routed score pass over packed 1.25-bit K pages must
        // satisfy both DESIGN.md §4 bounds, elementwise per (row, head):
        //   Bound 1 (vs dequantized K): the fused and dequant paths share
        //     the stored codes and scales, so they differ only by query
        //     rounding over the 3·hd/4 surviving lanes —
        //     ≤ (3/4)·hd·½·s_q·s_k;
        //   Bound 2 (vs exact f32 K): add the 3:4 drop mass and the
        //     absmean magnitude-snap error of the kept lanes.
        use crate::quant::absmean::sparsify34_codes;
        let cfg = nano();
        let d = cfg.d_model;
        let (nh, hd) = (cfg.n_heads, cfg.head_dim());
        let nb = hd / 4;
        let mut rng = crate::util::Pcg64::seeded(47);
        let mut alloc =
            crate::cache::BlockAllocator::new_with(&cfg, 4, 4, crate::cache::KvDtype::Ternary);
        let mut table = crate::cache::BlockTable::new(4);
        let mut krows: Vec<Vec<f32>> = Vec::new();
        for pos in 0..6usize {
            table.prepare_append(&mut alloc);
            let (page, slot) = table.slot_for(pos);
            let row = rng.normal_vec(d);
            alloc.write_row(0, page, slot, &row, &row);
            krows.push(row);
            table.advance();
        }
        let q = rng.normal_vec(d);
        let (mut codes, mut q_scales) = (Vec::new(), Vec::new());
        super::quantize_query(&q, nh, hd, &mut codes, &mut q_scales);
        let mut luts = vec![0.0f32; nh * nb * 32];
        lut::build_qk_luts34(&codes, hd, nh, &mut luts);
        let mut tables = [&mut table];
        let kv = KvBatch::Paged { alloc: &mut alloc, tables: &mut tables };
        let rows_view = kv.k_rows(0, 0);
        let mut scratch = Vec::new();
        // Reference: dequantized K pages dotted with the f32 query.
        let mut dequant = vec![0.0f32; nh * 6];
        rows_view.for_each_block(6, &mut scratch, |start, block, n| {
            for r in 0..n {
                for hh in 0..nh {
                    dequant[hh * 6 + start + r] = q[hh * hd..(hh + 1) * hd]
                        .iter()
                        .zip(&block[r * d + hh * hd..r * d + (hh + 1) * hd])
                        .map(|(x, y)| x * y)
                        .sum();
                }
            }
        });
        // Fused: the LUT walk over the raw packed planes.
        let mut fused = vec![0.0f32; nh * 6];
        let mut kscales = vec![0.0f32; nh * 6];
        rows_view.for_each_kblock(6, &mut scratch, |start, block, n| {
            let KBlock::Ternary(tb) = block else { panic!("ternary store") };
            let mut out = vec![0.0f32; n];
            for hh in 0..nh {
                lut::qk_lut34_rows(
                    tb.idx, tb.sign, tb.idx_bh, tb.sign_bh, nb, hh, nh, &luts, n, &mut out,
                );
                for (r, &raw) in out.iter().enumerate() {
                    fused[hh * 6 + start + r] = raw * (q_scales[hh] * tb.scales[hh]);
                    kscales[hh * 6 + start + r] = tb.scales[hh];
                }
            }
        });
        for pos in 0..6 {
            for hh in 0..nh {
                let s_k = kscales[hh * 6 + pos];
                let (f, dq) = (fused[hh * 6 + pos], dequant[hh * 6 + pos]);
                let b1 = 0.75 * hd as f32 * 0.5 * q_scales[hh] * s_k + 1e-5;
                assert!((f - dq).abs() <= b1, "pos {pos} head {hh}: {f} vs {dq} (bound {b1})");
            }
        }
        let mut kcodes = vec![0i8; d];
        for (pos, krow) in krows.iter().enumerate() {
            sparsify34_codes(krow, &mut kcodes);
            for hh in 0..nh {
                let s_k = kscales[hh * 6 + pos];
                let mut exact = 0.0f32;
                let mut b2 = 0.5 * q_scales[hh] * s_k * (0.75 * hd as f32);
                for c in hh * hd..(hh + 1) * hd {
                    exact += q[c] * krow[c];
                    if kcodes[c] == 0 {
                        b2 += q[c].abs() * krow[c].abs();
                    } else {
                        b2 += q[c].abs() * (krow[c].abs() - s_k).abs();
                    }
                }
                let f = fused[hh * 6 + pos];
                assert!(
                    (f - exact).abs() <= b2 + 1e-4,
                    "pos {pos} head {hh}: {f} vs exact {exact} (bound {b2})"
                );
            }
        }
        table.release_all(&mut alloc);
    }

    #[test]
    fn kv_cache_grows_and_clears() {
        let cfg = nano();
        let weights = random_weights(&cfg, 2);
        let model = TernaryModel::build(cfg, &weights, Format::I2S);
        let mut cache = KvCache::new(&cfg);
        let mut scratch = Scratch::default();
        model.forward_one(5, &mut cache, &mut scratch);
        model.forward_one(6, &mut cache, &mut scratch);
        assert_eq!(cache.len, 2);
        assert_eq!(cache.bytes(), 2 * 2 * 2 * cfg.d_model * 4);
        cache.clear();
        assert_eq!(cache.len, 0);
        assert_eq!(cache.bytes(), 0);
    }

    #[test]
    fn model_size_ordering_matches_table4() {
        let cfg = nano();
        let weights = random_weights(&cfg, 3);
        let sizes: Vec<usize> = Format::ALL
            .iter()
            .map(|&f| TernaryModel::build(cfg, &weights, f).bytes())
            .collect();
        // Format::ALL = [Dense, I2S, Tl2, Sherry]
        assert!(sizes[0] > sizes[1], "dense > i2s");
        assert!(sizes[1] > sizes[2], "i2s > tl2");
        assert!(sizes[2] > sizes[3], "tl2 > sherry");
    }

    #[test]
    fn forward_batch_matches_independent_streams_bit_for_bit() {
        // Three sequences with different prompts and lengths, decoded
        // (a) one stream at a time via forward_one and (b) fused via
        // forward_batch — logits must be identical, which is what makes
        // continuous batching invisible to request determinism.
        let cfg = nano();
        let weights = random_weights(&cfg, 9);
        let prompts: [&[u32]; 3] = [&[1, 2, 3, 4], &[9, 8], &[5, 5, 5]];
        let pool = crate::util::ThreadPool::new(2);
        for format in Format::ALL {
            let model = TernaryModel::build(cfg, &weights, format);
            let mut scratch = Scratch::default();
            // (a) independent streams
            let mut solo_caches: Vec<KvCache> = prompts.iter().map(|_| KvCache::new(&cfg)).collect();
            let mut solo_logits: Vec<Vec<f32>> = Vec::new();
            for (p, cache) in prompts.iter().zip(&mut solo_caches) {
                let mut logits = Vec::new();
                for &t in *p {
                    logits = model.forward_one(t, cache, &mut scratch);
                }
                solo_logits.push(logits);
            }
            // (b) batched: replay the same prompts position by position
            // over the ragged active set (like the server's prefill).
            let mut caches: Vec<KvCache> = prompts.iter().map(|_| KvCache::new(&cfg)).collect();
            let mut last: Vec<Vec<f32>> = vec![Vec::new(); prompts.len()];
            let max_len = prompts.iter().map(|p| p.len()).max().unwrap();
            for step in 0..max_len {
                let sel: Vec<usize> =
                    (0..prompts.len()).filter(|&i| step < prompts[i].len()).collect();
                let toks: Vec<u32> = sel.iter().map(|&i| prompts[i][step]).collect();
                let mut refs: Vec<&mut KvCache> = Vec::new();
                let mut rest: &mut [KvCache] = &mut caches;
                let mut taken = 0usize;
                for &i in &sel {
                    let (_, tail) = rest.split_at_mut(i - taken);
                    let (head, tail) = tail.split_at_mut(1);
                    refs.push(&mut head[0]);
                    rest = tail;
                    taken = i + 1;
                }
                let logits = model.forward_batch(&toks, &mut refs, &mut scratch, Some(&pool));
                for (row, &i) in sel.iter().enumerate() {
                    last[i] = logits.row(row).to_vec();
                }
            }
            for (i, (a, b)) in last.iter().zip(&solo_logits).enumerate() {
                assert_eq!(a, b, "{format:?} seq {i}");
                assert_eq!(caches[i].len, prompts[i].len());
            }
        }
    }

    #[test]
    fn sherry_decode_close_to_dense_of_same_quant() {
        // Same Sherry ternarization served via LUT vs dequantized-dense
        // must agree closely (numeric path differs only in summation
        // order).
        let cfg = nano();
        let weights = random_weights(&cfg, 4);
        let m_lut = TernaryModel::build(cfg, &weights, Format::Sherry);
        let mut scratch = Scratch::default();
        let mut cache = KvCache::new(&cfg);
        let l1 = m_lut.forward_one(7, &mut cache, &mut scratch);
        // dense path with sherry-quantized weights
        let mut dq = ModelWeights::new();
        for (k, v) in &weights {
            let is_linear = k.contains(".w") && !k.contains("norm");
            if is_linear {
                let q = crate::quant::quantize(v, crate::quant::Method::Sherry34, crate::quant::Granularity::PerChannel);
                dq.insert(k.clone(), q.dequant());
            } else {
                dq.insert(k.clone(), v.clone());
            }
        }
        let m_dense = TernaryModel::build(cfg, &dq, Format::Dense);
        let mut cache2 = KvCache::new(&cfg);
        let l2 = m_dense.forward_one(7, &mut cache2, &mut scratch);
        for (a, b) in l1.iter().zip(&l2) {
            assert!((a - b).abs() < 2e-2 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }
}
