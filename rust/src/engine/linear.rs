//! A quantized linear layer over any packing format, plus the dense f32
//! baseline — the unit the native transformer and the Table 4 benches are
//! built from.

use super::lut;
use crate::pack::{Format, Packed34, PackedI2S, PackedMatrix, PackedTl2};
use crate::quant::{quantize, Granularity, Method, Ternary};
use crate::tensor::{ops::gemv_f32, Mat};

/// Reusable scratch buffers for the LUT kernels (one per worker thread).
#[derive(Default, Clone)]
pub struct Scratch {
    luts34: Vec<f32>,
    luts_tl2: Vec<f32>,
}

impl Scratch {
    fn ensure34(&mut self, d_in: usize) -> &mut [f32] {
        let need = (d_in / 4) * 16;
        if self.luts34.len() < need {
            self.luts34.resize(need, 0.0);
        }
        &mut self.luts34[..need]
    }

    fn ensure_tl2(&mut self, d_in: usize) -> &mut [f32] {
        let need = d_in.div_ceil(3) * lut::TL2_LUT_STRIDE;
        if self.luts_tl2.len() < need {
            self.luts_tl2.resize(need, 0.0);
        }
        &mut self.luts_tl2[..need]
    }
}

/// Weight storage variants.
enum Weights {
    /// (d_out × d_in) row-major f32 — the BF16-stand-in baseline.
    Dense(Vec<f32>),
    Sherry(Packed34),
    Tl2(PackedTl2),
    I2s(PackedI2S),
}

/// One quantized linear layer: y = Wq · x (+α scaling inside the kernel).
pub struct QuantLinear {
    pub d_in: usize,
    pub d_out: usize,
    pub format: Format,
    weights: Weights,
}

impl QuantLinear {
    /// Quantize + pack a float weight matrix `w` (d_in × d_out, the
    /// Python convention) into `format`. Sherry format implies the
    /// Sherry34 quantizer; ternary baselines use AbsMean, matching the
    /// paper's Table 4 setup (BitNet-style models, per-channel scales).
    pub fn from_float(w: &Mat, format: Format) -> Self {
        let (d_in, d_out) = (w.rows, w.cols);
        let weights = match format {
            Format::Dense => Weights::Dense(w.transpose().data),
            Format::Sherry => {
                let q = quantize(w, Method::Sherry34, Granularity::PerChannel);
                Weights::Sherry(Packed34::from_ternary(&q))
            }
            Format::Tl2 => {
                let q = quantize(w, Method::AbsMean, Granularity::PerChannel);
                Weights::Tl2(PackedTl2::from_ternary(&q))
            }
            Format::I2S => {
                let q = quantize(w, Method::AbsMean, Granularity::PerChannel);
                Weights::I2s(PackedI2S::from_ternary(&q))
            }
        };
        Self { d_in, d_out, format, weights }
    }

    /// Pack an already-quantized matrix (QAT checkpoint path).
    pub fn from_ternary(q: &Ternary, format: Format) -> Self {
        let weights = match format {
            Format::Sherry => Weights::Sherry(Packed34::from_ternary(q)),
            Format::Tl2 => Weights::Tl2(PackedTl2::from_ternary(q)),
            Format::I2S => Weights::I2s(PackedI2S::from_ternary(q)),
            Format::Dense => Weights::Dense(q.dequant().transpose().data),
        };
        Self { d_in: q.d_in, d_out: q.d_out, format, weights }
    }

    /// y = W · x. `scratch` carries the LUT buffers.
    pub fn forward(&self, x: &[f32], y: &mut [f32], scratch: &mut Scratch) {
        debug_assert_eq!(x.len(), self.d_in);
        debug_assert_eq!(y.len(), self.d_out);
        match &self.weights {
            Weights::Dense(w) => gemv_f32(w, self.d_out, self.d_in, x, y),
            Weights::Sherry(p) => lut::gemv_pack34(p, x, scratch.ensure34(self.d_in), y),
            Weights::Tl2(p) => lut::gemv_tl2(p, x, scratch.ensure_tl2(self.d_in), y),
            Weights::I2s(p) => lut::gemv_i2s(p, x, y),
        }
    }

    /// Bytes of weight storage (+ per-channel scales where applicable).
    pub fn bytes(&self) -> usize {
        match &self.weights {
            Weights::Dense(w) => w.len() * 2, // accounted as bf16 (paper baseline)
            Weights::Sherry(p) => p.weight_bytes() + crate::pack::scale_bytes(self.d_out),
            Weights::Tl2(p) => p.weight_bytes() + crate::pack::scale_bytes(self.d_out),
            Weights::I2s(p) => p.weight_bytes() + crate::pack::scale_bytes(self.d_out),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn all_formats_forward_and_agree_on_ternary_weights() {
        // Build from the same AbsMean ternary so LUT kernels must agree
        // exactly with the dense product of the dequantized weights.
        let mut rng = Pcg64::seeded(0);
        let w = Mat::randn(&mut rng, 384, 96, 1.0);
        let q = quantize(&w, Method::AbsMean, Granularity::PerChannel);
        let x = rng.normal_vec(384);
        let mut scratch = Scratch::default();

        let dense = QuantLinear::from_ternary(&q, Format::Dense);
        let mut y_ref = vec![0.0; 96];
        dense.forward(&x, &mut y_ref, &mut scratch);

        for format in [Format::Tl2, Format::I2S] {
            let l = QuantLinear::from_ternary(&q, format);
            let mut y = vec![0.0; 96];
            l.forward(&x, &mut y, &mut scratch);
            for (a, b) in y.iter().zip(&y_ref) {
                assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "{format:?}");
            }
        }
    }

    #[test]
    fn sherry_linear_matches_dense_of_same_quant() {
        let mut rng = Pcg64::seeded(1);
        let w = Mat::randn(&mut rng, 256, 64, 1.0);
        let q = quantize(&w, Method::Sherry34, Granularity::PerChannel);
        let x = rng.normal_vec(256);
        let mut scratch = Scratch::default();
        let mut y = vec![0.0; 64];
        QuantLinear::from_ternary(&q, Format::Sherry).forward(&x, &mut y, &mut scratch);
        let mut y_ref = vec![0.0; 64];
        QuantLinear::from_ternary(&q, Format::Dense).forward(&x, &mut y_ref, &mut scratch);
        for (a, b) in y.iter().zip(&y_ref) {
            assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()));
        }
    }

    #[test]
    fn bytes_ordering() {
        let mut rng = Pcg64::seeded(2);
        let w = Mat::randn(&mut rng, 768, 768, 1.0);
        let sherry = QuantLinear::from_float(&w, Format::Sherry).bytes();
        let tl2 = QuantLinear::from_float(&w, Format::Tl2).bytes();
        let i2s = QuantLinear::from_float(&w, Format::I2S).bytes();
        let dense = QuantLinear::from_float(&w, Format::Dense).bytes();
        assert!(sherry < tl2 && tl2 < i2s && i2s < dense);
    }

    #[test]
    fn scratch_grows_monotonically() {
        let mut s = Scratch::default();
        assert_eq!(s.ensure34(64).len(), 16 * 16);
        assert_eq!(s.ensure34(16).len(), 4 * 16);
        assert!(s.luts34.len() >= 16 * 16);
    }
}
