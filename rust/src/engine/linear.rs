//! A quantized linear layer over any packing format, plus the dense f32
//! baseline — the unit the native transformer and the Table 4 benches are
//! built from.
//!
//! Storage and dispatch live behind one [`TernaryKernel`] object: the
//! per-format `Weights` enum this layer used to carry is gone, so adding a
//! packing format means implementing the trait, not growing a match.

use super::kernel::{DenseKernel, Scratch, TernaryKernel};
use crate::pack::{Format, Packed34, PackedI2S, PackedTl2};
use crate::quant::{quantize, Granularity, Method, Ternary};
use crate::tensor::Mat;
use crate::util::ThreadPool;

/// One quantized linear layer: y = Wq · x (+α scaling inside the kernel).
pub struct QuantLinear {
    pub d_in: usize,
    pub d_out: usize,
    pub format: Format,
    kernel: Box<dyn TernaryKernel>,
}

impl QuantLinear {
    /// Quantize + pack a float weight matrix `w` (d_in × d_out, the
    /// Python convention) into `format`. Sherry format implies the
    /// Sherry34 quantizer; ternary baselines use AbsMean, matching the
    /// paper's Table 4 setup (BitNet-style models, per-channel scales).
    pub fn from_float(w: &Mat, format: Format) -> Self {
        let (d_in, d_out) = (w.rows, w.cols);
        let kernel: Box<dyn TernaryKernel> = match format {
            Format::Dense => Box::new(DenseKernel::from_rows(d_in, d_out, w.transpose().data)),
            Format::Sherry => {
                let q = quantize(w, Method::Sherry34, Granularity::PerChannel);
                Box::new(Packed34::from_ternary(&q))
            }
            Format::Tl2 => {
                let q = quantize(w, Method::AbsMean, Granularity::PerChannel);
                Box::new(PackedTl2::from_ternary(&q))
            }
            Format::I2S => {
                let q = quantize(w, Method::AbsMean, Granularity::PerChannel);
                Box::new(PackedI2S::from_ternary(&q))
            }
        };
        Self { d_in, d_out, format, kernel }
    }

    /// Pack an already-quantized matrix (QAT checkpoint path).
    pub fn from_ternary(q: &Ternary, format: Format) -> Self {
        let kernel: Box<dyn TernaryKernel> = match format {
            Format::Sherry => Box::new(Packed34::from_ternary(q)),
            Format::Tl2 => Box::new(PackedTl2::from_ternary(q)),
            Format::I2S => Box::new(PackedI2S::from_ternary(q)),
            Format::Dense => {
                Box::new(DenseKernel::from_rows(q.d_in, q.d_out, q.dequant().transpose().data))
            }
        };
        Self { d_in: q.d_in, d_out: q.d_out, format, kernel }
    }

    /// y = W · x. `scratch` carries the LUT buffers.
    pub fn forward(&self, x: &[f32], y: &mut [f32], scratch: &mut Scratch) {
        debug_assert_eq!(x.len(), self.d_in);
        debug_assert_eq!(y.len(), self.d_out);
        self.kernel.gemv(x, y, scratch);
    }

    /// Batched Y = X·Wᵀ over `batch` activation rows (`xs`: batch × d_in,
    /// `ys`: batch × d_out). One fused LUT-GEMM pass; see
    /// [`TernaryKernel::gemm_nt`].
    pub fn forward_batch(
        &self,
        xs: &[f32],
        ys: &mut [f32],
        batch: usize,
        scratch: &mut Scratch,
        pool: Option<&ThreadPool>,
    ) {
        self.kernel.gemm_nt(xs, ys, batch, scratch, pool);
    }

    /// Borrow the underlying kernel (tests, size accounting).
    pub fn kernel(&self) -> &dyn TernaryKernel {
        &*self.kernel
    }

    /// Bytes of weight storage (+ per-channel scales where applicable).
    pub fn bytes(&self) -> usize {
        match self.format {
            // Dense already accounts its planes at bf16 width, no scales.
            Format::Dense => self.kernel.weight_bytes(),
            _ => self.kernel.weight_bytes() + crate::pack::scale_bytes(self.d_out),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn all_formats_forward_and_agree_on_ternary_weights() {
        // Build from the same AbsMean ternary so LUT kernels must agree
        // exactly with the dense product of the dequantized weights.
        let mut rng = Pcg64::seeded(0);
        let w = Mat::randn(&mut rng, 384, 96, 1.0);
        let q = quantize(&w, Method::AbsMean, Granularity::PerChannel);
        let x = rng.normal_vec(384);
        let mut scratch = Scratch::default();

        let dense = QuantLinear::from_ternary(&q, Format::Dense);
        let mut y_ref = vec![0.0; 96];
        dense.forward(&x, &mut y_ref, &mut scratch);

        for format in [Format::Tl2, Format::I2S] {
            let l = QuantLinear::from_ternary(&q, format);
            let mut y = vec![0.0; 96];
            l.forward(&x, &mut y, &mut scratch);
            for (a, b) in y.iter().zip(&y_ref) {
                assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "{format:?}");
            }
        }
    }

    #[test]
    fn sherry_linear_matches_dense_of_same_quant() {
        let mut rng = Pcg64::seeded(1);
        let w = Mat::randn(&mut rng, 256, 64, 1.0);
        let q = quantize(&w, Method::Sherry34, Granularity::PerChannel);
        let x = rng.normal_vec(256);
        let mut scratch = Scratch::default();
        let mut y = vec![0.0; 64];
        QuantLinear::from_ternary(&q, Format::Sherry).forward(&x, &mut y, &mut scratch);
        let mut y_ref = vec![0.0; 64];
        QuantLinear::from_ternary(&q, Format::Dense).forward(&x, &mut y_ref, &mut scratch);
        for (a, b) in y.iter().zip(&y_ref) {
            assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()));
        }
    }

    #[test]
    fn forward_batch_matches_forward_all_formats() {
        let mut rng = Pcg64::seeded(3);
        let w = Mat::randn(&mut rng, 128, 48, 1.0);
        let b = 4usize;
        let xs = rng.normal_vec(b * 128);
        for format in Format::ALL {
            let l = QuantLinear::from_float(&w, format);
            let mut scratch = Scratch::default();
            let mut singles = vec![0.0; b * 48];
            for bi in 0..b {
                let (x, y) = (&xs[bi * 128..(bi + 1) * 128], &mut singles[bi * 48..(bi + 1) * 48]);
                l.forward(x, y, &mut scratch);
            }
            let mut batched = vec![0.0; b * 48];
            l.forward_batch(&xs, &mut batched, b, &mut scratch, None);
            assert_eq!(batched, singles, "{format:?}");
        }
    }

    #[test]
    fn bytes_ordering() {
        let mut rng = Pcg64::seeded(2);
        let w = Mat::randn(&mut rng, 768, 768, 1.0);
        let sherry = QuantLinear::from_float(&w, Format::Sherry).bytes();
        let tl2 = QuantLinear::from_float(&w, Format::Tl2).bytes();
        let i2s = QuantLinear::from_float(&w, Format::I2S).bytes();
        let dense = QuantLinear::from_float(&w, Format::Dense).bytes();
        assert!(sherry < tl2 && tl2 < i2s && i2s < dense);
    }
}
