//! Numerical linear algebra substrate: one-sided Jacobi SVD and the
//! Effective Rank diagnostic (paper Eq. 21-22, App. F).
//!
//! ER is the metric behind Figs. 4 and 11: it measures the entropy of the
//! singular-value spectrum of a gradient matrix, diagnosing the gradient
//! homogenization that causes weight trapping.

use crate::tensor::Mat;

/// Singular values of `a` via one-sided Jacobi rotations on columns.
///
/// Accurate to ~1e-5 relative for the well-conditioned gradient matrices
/// we diagnose; O(n·m²) per sweep, fine for d ≤ 1k.
pub fn singular_values(a: &Mat) -> Vec<f32> {
    // Work on the thin side: svd(A) == svd(Aᵀ).
    let work = if a.rows < a.cols { a.transpose() } else { a.clone() };
    let (m, n) = (work.rows, work.cols);
    // Column-major copy for cache-friendly column ops.
    let mut u: Vec<Vec<f64>> = (0..n)
        .map(|j| (0..m).map(|i| work.at(i, j) as f64).collect())
        .collect();

    let max_sweeps = 60;
    let eps = 1e-12;
    for _ in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                for i in 0..m {
                    app += u[p][i] * u[p][i];
                    aqq += u[q][i] * u[q][i];
                    apq += u[p][i] * u[q][i];
                }
                off += apq.abs();
                if apq.abs() < eps * (app * aqq).sqrt().max(1e-300) {
                    continue;
                }
                // Jacobi rotation zeroing the (p,q) inner product.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let up = u[p][i];
                    let uq = u[q][i];
                    u[p][i] = c * up - s * uq;
                    u[q][i] = s * up + c * uq;
                }
            }
        }
        if off < 1e-10 {
            break;
        }
    }
    let mut sv: Vec<f32> = u
        .iter()
        .map(|col| (col.iter().map(|x| x * x).sum::<f64>()).sqrt() as f32)
        .collect();
    sv.sort_by(|a, b| b.total_cmp(a)); // NaN-safe: NaNs sort last
    sv
}

/// Effective Rank (paper Eq. 21-22): exp of the Shannon entropy of the
/// normalized singular-value distribution. Ranges in [1, min(m,n)].
pub fn effective_rank(g: &Mat) -> f32 {
    let sv = singular_values(g);
    let total: f64 = sv.iter().map(|&s| s as f64).sum();
    if total <= 0.0 {
        return 1.0;
    }
    let mut h = 0.0f64;
    for &s in &sv {
        let p = s as f64 / total;
        if p > 1e-12 {
            h -= p * p.ln();
        }
    }
    h.exp() as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn sv_of_diagonal() {
        let mut m = Mat::zeros(3, 3);
        *m.at_mut(0, 0) = 3.0;
        *m.at_mut(1, 1) = 2.0;
        *m.at_mut(2, 2) = 1.0;
        let sv = singular_values(&m);
        assert!((sv[0] - 3.0).abs() < 1e-4);
        assert!((sv[1] - 2.0).abs() < 1e-4);
        assert!((sv[2] - 1.0).abs() < 1e-4);
    }

    #[test]
    fn sv_invariant_to_transpose() {
        let mut rng = Pcg64::seeded(2);
        let a = Mat::randn(&mut rng, 10, 6, 1.0);
        let s1 = singular_values(&a);
        let s2 = singular_values(&a.transpose());
        for (x, y) in s1.iter().zip(&s2) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn frobenius_identity() {
        // Σσ² == ‖A‖²_F
        let mut rng = Pcg64::seeded(3);
        let a = Mat::randn(&mut rng, 12, 8, 1.0);
        let sv = singular_values(&a);
        let sum_sq: f32 = sv.iter().map(|s| s * s).sum();
        assert!((sum_sq - a.frob().powi(2)).abs() / sum_sq < 1e-4);
    }

    #[test]
    fn er_identity_is_full_rank() {
        let mut eye = Mat::zeros(16, 16);
        for i in 0..16 {
            *eye.at_mut(i, i) = 1.0;
        }
        assert!((effective_rank(&eye) - 16.0).abs() < 1e-2);
    }

    #[test]
    fn er_rank_one_is_one() {
        let mut m = Mat::zeros(8, 8);
        for i in 0..8 {
            for j in 0..8 {
                *m.at_mut(i, j) = (i + 1) as f32 * (j + 1) as f32;
            }
        }
        assert!((effective_rank(&m) - 1.0).abs() < 1e-2);
    }

    #[test]
    fn er_bounded_by_min_dim() {
        let mut rng = Pcg64::seeded(7);
        let m = Mat::randn(&mut rng, 20, 9, 1.0);
        let er = effective_rank(&m);
        assert!(er >= 1.0 && er <= 9.0 + 1e-3, "er {er}");
    }

    #[test]
    fn er_matches_python_golden() {
        // Golden vectors produced by python/compile/golden.py; skip if the
        // artifacts have not been built.
        let dir = crate::test_artifacts_dir();
        let g1 = dir.join("golden/er_g1.bin");
        if !g1.exists() {
            eprintln!("skipping: golden vectors not built (run `make artifacts`)");
            return;
        }
        let (r, c, d) = crate::util::binio::read_mat(&g1).unwrap();
        let m1 = Mat::from_vec(r, c, d);
        let (r2, c2, d2) = crate::util::binio::read_mat(&dir.join("golden/er_g2.bin")).unwrap();
        let m2 = Mat::from_vec(r2, c2, d2);
        let (_, _, expect) = crate::util::binio::read_mat(&dir.join("golden/er_expected.bin")).unwrap();
        assert!((effective_rank(&m1) - expect[0]).abs() / expect[0] < 2e-3);
        assert!((effective_rank(&m2) - expect[1]).abs() / expect[1] < 2e-3);
    }
}
