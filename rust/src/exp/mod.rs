//! Experiment drivers: one per paper table/figure (see DESIGN.md §3).
//!
//! Accuracy experiments (QAT runs) are CLI subcommands (`sherry exp <id>`)
//! because they take minutes; timing experiments live in `rust/benches/`.
//! Every driver writes its artifact under `results/` and prints a summary.

mod figures;
mod tables;

pub use figures::{fig10_11, fig3, fig4, fig6, fig7, fig8};
pub use tables::{table1, table2, table3, MethodRow};

use anyhow::Result;
use std::path::PathBuf;

/// Output directory for experiment artifacts.
pub fn results_dir() -> PathBuf {
    let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("results");
    let _ = std::fs::create_dir_all(&d);
    d
}

/// Write + echo an experiment artifact.
pub fn emit(name: &str, content: &str) -> Result<()> {
    let path = results_dir().join(name);
    std::fs::write(&path, content)?;
    println!("{content}");
    println!("[exp] wrote {}", path.display());
    Ok(())
}

/// Simple ASCII horizontal bar (for figure summaries in the terminal).
pub fn bar(value: f32, max: f32, width: usize) -> String {
    let n = ((value / max).clamp(0.0, 1.0) * width as f32).round() as usize;
    "█".repeat(n)
}

/// Render a histogram as ASCII rows + TSV block.
pub fn render_histogram(title: &str, edges_lo: f32, edges_hi: f32, counts: &[u64]) -> String {
    let max = counts.iter().copied().max().unwrap_or(1).max(1) as f32;
    let bins = counts.len();
    let w = (edges_hi - edges_lo) / bins as f32;
    let mut s = format!("#### {title}\n```\n");
    for (i, &c) in counts.iter().enumerate() {
        let lo = edges_lo + i as f32 * w;
        s.push_str(&format!("{lo:>7.2} | {:<40} {c}\n", bar(c as f32, max, 40)));
    }
    s.push_str("```\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bar_widths() {
        assert_eq!(bar(1.0, 1.0, 10).chars().count(), 10);
        assert_eq!(bar(0.0, 1.0, 10).chars().count(), 0);
        assert_eq!(bar(2.0, 1.0, 10).chars().count(), 10); // clamped
    }

    #[test]
    fn histogram_renders_all_bins() {
        let s = render_histogram("t", -1.0, 1.0, &[1, 5, 2]);
        assert_eq!(s.matches('|').count(), 3);
    }
}
