//! Figures 3/4/6/7/8/10/11: the weight-trapping and Arenas diagnostics.

use anyhow::Result;
use std::collections::BTreeMap;

use crate::linalg::effective_rank;
use crate::quant::{lambda_at, Schedule};
use crate::runtime::Runtime;
use crate::tensor::Mat;
use crate::train::{train_and_eval, TrainConfig, Trainer};
use crate::util::stats;

use super::{emit, render_histogram};

fn train_cfg(method: &str, schedule: Schedule, steps: usize, seed: u64) -> TrainConfig {
    TrainConfig {
        method: method.into(),
        schedule,
        steps,
        seed,
        er_layer: "layer0.wq".into(),
        ..Default::default()
    }
}

/// Normalized latent-weight histogram of every attention/MLP linear
/// (weights divided by their per-channel abs-mean, matching the paper's
/// Fig. 3 normalization).
fn weight_histogram(params: &BTreeMap<String, Mat>, bins: usize, lo: f32, hi: f32) -> Vec<u64> {
    let mut normed = Vec::new();
    for (name, w) in params {
        if !name.contains("layer") || name.contains("norm") || name.ends_with(".aux") {
            continue;
        }
        for j in 0..w.cols {
            let col = w.col(j);
            let am = col.iter().map(|x| x.abs()).sum::<f32>() / col.len() as f32;
            if am > 0.0 {
                normed.extend(col.iter().map(|x| x / am));
            }
        }
    }
    stats::histogram(&normed, lo, hi, bins)
}

/// Fig. 3: weight distributions — naive 3:4 training (weight trapping,
/// binary-like polarization) vs Sherry with Arenas (trap-free).
pub fn fig3(rt: &mut Runtime, steps: usize, seed: u64) -> Result<String> {
    let mut out = String::from("### Fig 3 — weight trapping vs Arenas (latent w / E|w|)\n\n");
    let mut polarization = Vec::new();
    for (label, schedule) in [("naive 3:4 (no Arenas)", Schedule::Off), ("Sherry (Arenas cosine-warmup)", Schedule::CosineWarmup)] {
        eprintln!("[fig3] training {label}...");
        let cfg = train_cfg("sherry34", schedule, steps, seed);
        let mut trainer = Trainer::new(rt, &cfg)?;
        let o = trainer.run(&cfg)?;
        let h = weight_histogram(&o.params, 41, -3.0, 3.0);
        out.push_str(&render_histogram(label, -3.0, 3.0, &h));
        // Polarization metric: mass in |w/E|w|| ∈ [0.8, 1.6] (the ±α
        // attractors) vs mass near zero — high ratio = trapped/binary-like.
        let total: u64 = h.iter().sum();
        let bin_of = |x: f32| (((x + 3.0) / 6.0) * 41.0) as usize;
        let near_alpha: u64 = h[bin_of(-1.6)..bin_of(-0.8)].iter().sum::<u64>()
            + h[bin_of(0.8)..bin_of(1.6)].iter().sum::<u64>();
        let near_zero: u64 = h[bin_of(-0.3)..bin_of(0.3)].iter().sum();
        let pol = near_alpha as f32 / (near_zero.max(1)) as f32;
        out.push_str(&format!(
            "mass near ±α: {:.3}, near 0: {:.3}, polarization ratio: {pol:.2}\n\n",
            near_alpha as f32 / total as f32,
            near_zero as f32 / total as f32,
        ));
        polarization.push(pol);
    }
    out.push_str(&format!(
        "**Paper shape check**: naive polarization ({:.2}) > Arenas polarization ({:.2}) → {}\n",
        polarization[0],
        polarization[1],
        if polarization[0] > polarization[1] { "REPRODUCED" } else { "NOT reproduced" }
    ));
    emit("fig3_trapping.md", &out)?;
    Ok(out)
}

/// Fig. 4: effective rank of gradients during training for binary, naive
/// 3:4, and both with Arenas.
pub fn fig4(rt: &mut Runtime, steps: usize, seed: u64) -> Result<String> {
    let mut out = String::from("### Fig 4 — effective rank of ∂L/∂W (layer0.wq) during training\n\n");
    out.push_str("| step |");
    let arms: &[(&str, &str, Schedule)] = &[
        ("binary", "binary", Schedule::Off),
        ("3:4 naive", "sherry34", Schedule::Off),
        ("binary+Arenas", "binary", Schedule::CosineWarmup),
        ("Sherry (3:4+Arenas)", "sherry34", Schedule::CosineWarmup),
        ("absmean (dense ternary)", "absmean", Schedule::Off),
    ];
    let mut traces: Vec<Vec<(usize, f32)>> = Vec::new();
    for (label, method, schedule) in arms {
        eprintln!("[fig4] training {label}...");
        let mut cfg = train_cfg(method, *schedule, steps, seed);
        cfg.er_every = (steps / 10).max(1);
        let mut trainer = Trainer::new(rt, &cfg)?;
        let o = trainer.run(&cfg)?;
        traces.push(o.er_trace);
        out.push_str(&format!(" {label} |"));
    }
    out.push('\n');
    out.push_str(&"|---".repeat(arms.len() + 1));
    out.push_str("|\n");
    for k in 0..traces[0].len() {
        out.push_str(&format!("| {} |", traces[0][k].0));
        for tr in &traces {
            out.push_str(&format!(" {:.1} |", tr.get(k).map(|x| x.1).unwrap_or(f32::NAN)));
        }
        out.push('\n');
    }
    // Shape check: mean ER of Arenas arm > naive arm (paper: naive/binary
    // collapse; Arenas restores diversity).
    let mean_er = |tr: &Vec<(usize, f32)>| tr.iter().map(|x| x.1 as f64).sum::<f64>() / tr.len() as f64;
    let naive = mean_er(&traces[1]);
    let arenas = mean_er(&traces[3]);
    out.push_str(&format!(
        "\n**Paper shape check**: ER(Sherry+Arenas) {arenas:.1} > ER(naive 3:4) {naive:.1} → {}\n",
        if arenas > naive { "REPRODUCED" } else { "NOT reproduced" }
    ));
    emit("fig4_effective_rank.md", &out)?;
    Ok(out)
}

/// Fig. 6: Arenas ablation across binary (1-bit), 3:4 (1.25-bit) and
/// dense ternary absmean (1.67-bit).
pub fn fig6(rt: &mut Runtime, steps: usize, n_q: usize, seed: u64) -> Result<String> {
    let mut out = String::from("### Fig 6 — Arenas ablation (average accuracy)\n\n| scheme | w/o Arenas | w/ Arenas | Δ |\n|---|---|---|---|\n");
    let mut all_gains = Vec::new();
    for (label, method) in [("binary (1-bit)", "binary"), ("3:4 sparse (1.25-bit)", "sherry34"), ("AbsMean (1.67-bit)", "absmean")] {
        eprintln!("[fig6] {label}...");
        let without = super::tables::run_method(rt, "nano", method, "per_channel", Schedule::Off, steps, n_q, seed)?;
        let with = super::tables::run_method(rt, "nano", method, "per_channel", Schedule::CosineWarmup, steps, n_q, seed)?;
        let delta = with.row.average - without.row.average;
        all_gains.push(delta);
        out.push_str(&format!(
            "| {label} | {:.3} | {:.3} | {delta:+.3} |\n",
            without.row.average, with.row.average
        ));
    }
    out.push_str(&format!(
        "\n**Paper shape check**: Arenas helps every scheme → {}\n",
        if all_gains.iter().all(|&g| g >= -0.02) { "REPRODUCED (within noise)" } else { "NOT reproduced" }
    ));
    emit("fig6_arenas_ablation.md", &out)?;
    Ok(out)
}

/// Fig. 7: λ_t schedule curves (closed-form; TSV for plotting).
pub fn fig7() -> Result<String> {
    let mut out = String::from("### Fig 7 — λ_t schedules\n\np\t");
    for s in Schedule::ALL.iter().skip(1) {
        out.push_str(&format!("{}\t", s.name()));
    }
    out.push('\n');
    for k in 0..=50 {
        let p = k as f32 / 50.0;
        out.push_str(&format!("{p:.2}\t"));
        for s in Schedule::ALL.iter().skip(1) {
            out.push_str(&format!("{:.4}\t", lambda_at(*s, p)));
        }
        out.push('\n');
    }
    emit("fig7_schedules.tsv", &out)?;
    Ok(out)
}

/// Fig. 8: accuracy per λ_t schedule (3 decays × ±warmup vs no Arenas).
pub fn fig8(rt: &mut Runtime, steps: usize, n_q: usize, seed: u64) -> Result<String> {
    let mut out = String::from("### Fig 8 — λ_t schedule comparison (Sherry, average accuracy)\n\n| schedule | avg acc |\n|---|---|\n");
    let mut base_acc = 0.0;
    let mut accs = Vec::new();
    for s in Schedule::ALL {
        eprintln!("[fig8] schedule {}...", s.name());
        let r = super::tables::run_method(rt, "nano", "sherry34", "per_channel", s, steps, n_q, seed)?;
        out.push_str(&format!("| {} | {:.3} |\n", s.name(), r.row.average));
        if s == Schedule::Off {
            base_acc = r.row.average;
        } else {
            accs.push((s, r.row.average));
        }
    }
    let n_better = accs.iter().filter(|(_, a)| *a >= base_acc - 0.02).count();
    out.push_str(&format!(
        "\n**Paper shape check**: schedules ≥ no-Arenas baseline: {n_better}/{} → {}\n",
        accs.len(),
        if n_better >= accs.len() - 1 { "REPRODUCED (within noise)" } else { "PARTIAL" }
    ));
    emit("fig8_schedule_comparison.md", &out)?;
    Ok(out)
}

/// Figs. 10-11: weight distributions + per-layer gradient ER across
/// regimes (binary / 3:4 / absmean, each ± Arenas).
pub fn fig10_11(rt: &mut Runtime, steps: usize, seed: u64) -> Result<String> {
    let mut out = String::from("### Figs 10-11 — distributions & per-layer ER across regimes\n\n");
    for (label, method, schedule) in [
        ("binary", "binary", Schedule::Off),
        ("binary + Arenas", "binary", Schedule::CosineWarmup),
        ("3:4 naive", "sherry34", Schedule::Off),
        ("Sherry (3:4 + Arenas)", "sherry34", Schedule::CosineWarmup),
        ("absmean", "absmean", Schedule::Off),
        ("absmean + Arenas", "absmean", Schedule::CosineWarmup),
    ] {
        eprintln!("[fig10] {label}...");
        let cfg = train_cfg(method, schedule, steps, seed);
        let (o, _) = train_and_eval(rt, &cfg, 1)?;
        let h = weight_histogram(&o.params, 41, -3.0, 3.0);
        out.push_str(&render_histogram(label, -3.0, 3.0, &h));
        // per-layer final-weight ER as the structural diversity proxy
        out.push_str("per-layer ER of final latent weights: ");
        for (name, w) in &o.params {
            if name.ends_with(".wq") || name.ends_with(".w_down") {
                out.push_str(&format!("{}={:.1} ", name, effective_rank(w)));
            }
        }
        out.push_str("\n\n");
    }
    emit("fig10_11_distributions.md", &out)?;
    Ok(out)
}
