//! Tables 1-3: QAT training sweeps + synthetic-benchmark evaluation.
//!
//! Protocol (scaled per DESIGN.md substitutions): for each method, run the
//! AOT QAT train-step for `steps` steps on the synthetic corpus, PTQ the
//! trained latents, evaluate on the five tasks. Sherry trains with Arenas
//! (cosine-warmup); baselines train as published (no residual). The BF16
//! row trains the identity "quantizer".

use anyhow::Result;
use std::collections::BTreeMap;

use crate::engine::{NativeConfig, TernaryModel};
use crate::eval::{evaluate, evaluate_ptq, render_table, EvalRow};
use crate::pack::Format;
use crate::quant::{Granularity, Method, Schedule};
use crate::runtime::Runtime;
use crate::tensor::Mat;
use crate::train::{train_and_eval, TrainConfig};

/// One trained + evaluated method.
#[derive(Clone, Debug)]
pub struct MethodRow {
    pub method: String,
    pub row: EvalRow,
    pub final_train_loss: f32,
    pub eval_loss: f32,
}

/// Train one method (QAT via PJRT) and evaluate it natively.
pub fn run_method(
    rt: &mut Runtime,
    config: &str,
    method: &str,
    granularity: &str,
    schedule: Schedule,
    steps: usize,
    n_q: usize,
    seed: u64,
) -> Result<MethodRow> {
    let cfg = TrainConfig {
        config: config.into(),
        method: method.into(),
        granularity: granularity.into(),
        steps,
        schedule,
        seed,
        ..Default::default()
    };
    let (outcome, eval_loss) = train_and_eval(rt, &cfg, 2)?;
    let native_cfg = NativeConfig::named(config).expect("known config");
    let gran = Granularity::parse(granularity, 128).expect("granularity");
    let row = if method == "bf16" {
        let model = TernaryModel::build(native_cfg, &strip_aux(&outcome.params), Format::Dense);
        evaluate("BF16", 16.0, &model, native_cfg.vocab_size, n_q, seed)
    } else {
        let m = Method::parse(method).expect("method");
        evaluate_ptq(method, native_cfg, &outcome.params, m, gran, n_q, seed)
    };
    Ok(MethodRow {
        method: method.into(),
        row,
        final_train_loss: *outcome.losses.last().unwrap_or(&f32::NAN),
        eval_loss,
    })
}

fn strip_aux(params: &BTreeMap<String, Mat>) -> BTreeMap<String, Mat> {
    params
        .iter()
        .filter(|(k, _)| !k.ends_with(".aux"))
        .map(|(k, v)| (k.clone(), v.clone()))
        .collect()
}

/// Table 1: ternary quantization method comparison.
pub fn table1(rt: &mut Runtime, steps: usize, n_q: usize, seed: u64) -> Result<String> {
    // (label, method, schedule) — Sherry is the only Arenas user, as in
    // the paper's Table 1.
    let rows_spec: &[(&str, &str, Schedule)] = &[
        ("BF16", "bf16", Schedule::Off),
        ("LSQ", "lsq", Schedule::Off),
        ("SEQ", "seq", Schedule::Off),
        ("DLT", "dlt", Schedule::Off),
        ("TWN", "twn", Schedule::Off),
        ("AbsMedian", "absmedian", Schedule::Off),
        ("AbsMean", "absmean", Schedule::Off),
        ("Tequila", "tequila", Schedule::Off),
        ("Sherry", "sherry34", Schedule::CosineWarmup),
    ];
    let mut rows = Vec::new();
    for (label, method, schedule) in rows_spec {
        eprintln!("[table1] training {method} ({steps} steps)...");
        let mut r = run_method(rt, "nano", method, "per_channel", *schedule, steps, n_q, seed)?;
        r.row.label = label.to_string();
        rows.push(r);
    }
    let eval_rows: Vec<EvalRow> = rows.iter().map(|r| r.row.clone()).collect();
    let mut out = render_table("Table 1 — ternary quantization methods (nano scale)", &eval_rows);
    out.push_str("\nTrain/eval losses:\n");
    for r in &rows {
        out.push_str(&format!(
            "  {:<12} train {:.3}  eval {:.3}\n",
            r.method, r.final_train_loss, r.eval_loss
        ));
    }
    super::emit("table1_methods.md", &out)?;
    Ok(out)
}

/// Table 2: LLM-system comparison — same harness, rows labeled by the
/// system each quantizer represents.
pub fn table2(rt: &mut Runtime, steps: usize, n_q: usize, seed: u64) -> Result<String> {
    let rows_spec: &[(&str, &str, Schedule)] = &[
        ("LLaMA (BF16)", "bf16", Schedule::Off),
        ("TernaryLLM* (DLT)", "dlt", Schedule::Off),
        ("ParetoQ* (SEQ)", "seq", Schedule::Off),
        ("LLM-QAT (LSQ)", "lsq", Schedule::Off),
        ("BitNet (AbsMean)", "absmean", Schedule::Off),
        ("Spectra (AbsMedian)", "absmedian", Schedule::Off),
        ("TequilaLLM", "tequila", Schedule::Off),
        ("SherryLLM", "sherry34", Schedule::CosineWarmup),
    ];
    let mut eval_rows = Vec::new();
    for (label, method, schedule) in rows_spec {
        eprintln!("[table2] training {method} ({steps} steps)...");
        let mut r = run_method(rt, "nano", method, "per_channel", *schedule, steps, n_q, seed)?;
        r.row.label = label.to_string();
        eval_rows.push(r.row);
    }
    let out = render_table("Table 2 — SherryLLM vs ternary LLMs (nano scale)", &eval_rows);
    super::emit("table2_llms.md", &out)?;
    Ok(out)
}

/// Table 3: Sherry accuracy across quantization granularities, mean ± std
/// over `n_seeds` seeds.
pub fn table3(rt: &mut Runtime, steps: usize, n_q: usize, n_seeds: u64) -> Result<String> {
    let mut out = String::from("### Table 3 — Sherry across quantization granularities\n\n");
    out.push_str("| Granularity | Average Acc ± Std |\n|---|---|\n");
    for gran in ["per_tensor", "per_channel", "per_group"] {
        let mut accs = Vec::new();
        for seed in 0..n_seeds {
            eprintln!("[table3] {gran} seed {seed} ({steps} steps)...");
            let r = run_method(rt, "nano", "sherry34", gran, Schedule::CosineWarmup, steps, n_q, seed)?;
            accs.push(r.row.average as f64);
        }
        let mean = crate::util::stats::mean(&accs);
        let std = crate::util::stats::std_dev(&accs);
        out.push_str(&format!("| {gran} | {mean:.3} ± {std:.3} |\n"));
    }
    super::emit("table3_granularity.md", &out)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strip_aux_removes_only_aux() {
        let mut p = BTreeMap::new();
        p.insert("embed".to_string(), Mat::zeros(2, 2));
        p.insert("layer0.wq".to_string(), Mat::zeros(2, 2));
        p.insert("layer0.wq.aux".to_string(), Mat::zeros(1, 2));
        let s = strip_aux(&p);
        assert_eq!(s.len(), 2);
        assert!(!s.contains_key("layer0.wq.aux"));
    }
}
