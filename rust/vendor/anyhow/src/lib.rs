//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The hermetic build cannot reach crates.io, so this vendored shim
//! implements exactly the subset the workspace uses: [`Error`],
//! [`Result`], the [`Context`] extension trait, and the `anyhow!`,
//! `bail!`, `ensure!` macros. Error values carry a flattened message
//! chain (outermost context first), matching how the real crate's
//! `{:#}`/`Debug` output reads for simple string chains.

use std::fmt;

/// A string-chain error. Like the real `anyhow::Error`, this type
/// deliberately does **not** implement `std::error::Error`, which is what
/// makes the blanket `From` conversion below coherent.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Self { msg: m.to_string() }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(self, ctx: C) -> Self {
        Self { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Self { msg: e.to_string() }
    }
}

/// `anyhow::Result<T>` — defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{ctx}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error { msg: ctx.to_string() })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error { msg: f().to_string() })
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error when the condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/nonexistent/definitely/missing")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert!(io_fail().is_err());
    }

    #[test]
    fn context_chains_outermost_first() {
        let e: Result<()> = Err(anyhow!("inner"));
        let e = e.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert_eq!(v.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(3u32).with_context(|| "x").unwrap(), 3);
    }

    #[test]
    fn ensure_and_bail() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            if x == 7 {
                bail!("unlucky");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(12).unwrap_err().to_string().contains("too big"));
        assert!(f(7).unwrap_err().to_string().contains("unlucky"));
    }

    #[test]
    fn bare_ensure() {
        fn f(x: u32) -> Result<()> {
            ensure!(x > 0);
            Ok(())
        }
        assert!(f(0).unwrap_err().to_string().contains("x > 0"));
    }
}
